//! Serving metrics: counters + latency reservoirs + histograms,
//! rendered for the `ptqtp serve --report` output and the Table 5/6
//! style benches, and exported as the `serve-metrics.json` artifact
//! (`--metrics-json`).

use super::request::Response;
use crate::serialize::Json;
use std::time::Duration;

/// Log-spaced bucket upper bounds (milliseconds) for the latency
/// histograms; the implicit last bucket is +∞ overflow.
pub const LATENCY_BUCKET_BOUNDS_MS: [u64; 12] =
    [1, 2, 5, 10, 25, 50, 100, 250, 500, 1000, 2500, 5000];

const N_BUCKETS: usize = LATENCY_BUCKET_BOUNDS_MS.len() + 1;

/// Fixed-bucket latency histogram. Unlike the percentile reservoirs it
/// never saturates — every sample lands in a bucket — so it stays
/// faithful under long serves, and merging across replicas is exact
/// (bucket-wise addition).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LatencyHistogram {
    counts: [u64; N_BUCKETS],
    samples: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            counts: [0; N_BUCKETS],
            samples: 0,
        }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let ms = u64::try_from(d.as_millis()).unwrap_or(u64::MAX);
        let idx = LATENCY_BUCKET_BOUNDS_MS
            .iter()
            .position(|&bound| ms <= bound)
            .unwrap_or(LATENCY_BUCKET_BOUNDS_MS.len());
        self.counts[idx] += 1;
        self.samples += 1;
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Bucket counts, bound-aligned with
    /// [`LATENCY_BUCKET_BOUNDS_MS`] plus the trailing overflow bucket.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Exact bucket-wise merge (replica aggregation).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.samples += other.samples;
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("bounds_ms", LATENCY_BUCKET_BOUNDS_MS.to_vec())
            .set("counts", self.counts.to_vec())
    }
}

/// Engine-level metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub submitted: u64,
    /// Finished **responses** (an `n`-sample request contributes `n`).
    pub completed: u64,
    pub rejected: u64,
    /// Requests cancelled via their handle (request-granular).
    pub cancelled: u64,
    /// Requests retired by deadline expiry (request-granular).
    pub deadline_expired: u64,
    /// Requests (not samples) that ran to a normal finish — stop,
    /// length, or cache overflow. Together with `rejected`,
    /// `cancelled`, and `deadline_expired` this partitions every
    /// request the engine accepted.
    pub requests_finished: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    /// Prompt tokens satisfied by prefix-cache page adoption instead of
    /// prefill compute.
    pub adopted_tokens: u64,
    /// Prefix-cache admissions that adopted ≥ 1 page / total lookups.
    pub prefix_hits: u64,
    pub prefix_lookups: u64,
    /// Prefix-tree pages evicted under page-pool pressure.
    pub prefix_evicted_pages: u64,
    /// Sequences evicted for recompute under page exhaustion.
    pub preemptions: u64,
    /// Speculative decoding: draft tokens scored as verify rows.
    pub spec_drafted: u64,
    /// Speculative decoding: draft tokens whose verifier argmax
    /// matched — committed without their own forward pass.
    pub spec_accepted: u64,
    /// KV pages released by speculative rollback (rejected draft
    /// positions and over-reserved pages returned by `truncate`).
    pub spec_rollback_pages: u64,
    /// Copy-on-write page copies (forks writing into shared pages).
    pub cow_pages: u64,
    /// Page-pool gauges, refreshed by the engine each step.
    pub pages_in_use: usize,
    pub pages_free: usize,
    pub pages_peak: usize,
    pub page_budget: usize,
    /// Intake-queue gauges: depth at the last step, and the deepest
    /// the queue has ever been.
    pub queue_depth: usize,
    pub queue_depth_peak: usize,
    /// TTFT / end-to-end latency histograms over completed responses.
    pub ttft_hist: LatencyHistogram,
    pub total_hist: LatencyHistogram,
    /// Completed responses retained for percentile queries (bounded).
    pub finished: Vec<Response>,
    ttft_samples: Vec<Duration>,
    total_samples: Vec<Duration>,
}

const RESERVOIR: usize = 4096;

impl Metrics {
    pub fn record_response(&mut self, r: &Response) {
        self.completed += 1;
        self.ttft_hist.record(r.ttft);
        self.total_hist.record(r.total);
        if self.ttft_samples.len() < RESERVOIR {
            self.ttft_samples.push(r.ttft);
            self.total_samples.push(r.total);
        }
        if self.finished.len() < RESERVOIR {
            self.finished.push(r.clone());
        }
    }

    pub fn ttft_percentile(&self, p: f64) -> Option<Duration> {
        percentile(&self.ttft_samples, p)
    }

    pub fn total_percentile(&self, p: f64) -> Option<Duration> {
        percentile(&self.total_samples, p)
    }

    /// Tokens/second over a wall-clock window.
    pub fn throughput(&self, wall: Duration) -> f64 {
        self.decode_tokens as f64 / wall.as_secs_f64().max(1e-9)
    }

    /// Prefix-cache hit rate over admissions (0 when the cache is off
    /// or nothing was admitted).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }

    /// Fraction of speculative draft tokens the verifier accepted
    /// (0 when speculation is off or never fired).
    pub fn draft_accept_rate(&self) -> f64 {
        if self.spec_drafted == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_drafted as f64
        }
    }

    /// Sum counters / merge histograms across replica snapshots.
    /// Gauges (`pages_*`, `queue_depth*`) sum too, reading as
    /// fleet-wide totals; the percentile reservoirs concatenate up to
    /// their bound.
    pub fn merge_from(&mut self, other: &Metrics) {
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.cancelled += other.cancelled;
        self.deadline_expired += other.deadline_expired;
        self.requests_finished += other.requests_finished;
        self.prefill_tokens += other.prefill_tokens;
        self.decode_tokens += other.decode_tokens;
        self.adopted_tokens += other.adopted_tokens;
        self.prefix_hits += other.prefix_hits;
        self.prefix_lookups += other.prefix_lookups;
        self.prefix_evicted_pages += other.prefix_evicted_pages;
        self.preemptions += other.preemptions;
        self.spec_drafted += other.spec_drafted;
        self.spec_accepted += other.spec_accepted;
        self.spec_rollback_pages += other.spec_rollback_pages;
        self.cow_pages += other.cow_pages;
        self.pages_in_use += other.pages_in_use;
        self.pages_free += other.pages_free;
        self.pages_peak += other.pages_peak;
        self.page_budget += other.page_budget;
        self.queue_depth += other.queue_depth;
        self.queue_depth_peak += other.queue_depth_peak;
        self.ttft_hist.merge(&other.ttft_hist);
        self.total_hist.merge(&other.total_hist);
        let room = RESERVOIR.saturating_sub(self.ttft_samples.len());
        self.ttft_samples
            .extend(other.ttft_samples.iter().take(room).copied());
        let room = RESERVOIR.saturating_sub(self.total_samples.len());
        self.total_samples
            .extend(other.total_samples.iter().take(room).copied());
        let room = RESERVOIR.saturating_sub(self.finished.len());
        self.finished.extend(other.finished.iter().take(room).cloned());
    }

    /// Fleet aggregate of per-replica snapshots.
    pub fn aggregate(replicas: &[Metrics]) -> Metrics {
        let mut agg = Metrics::default();
        for m in replicas {
            agg.merge_from(m);
        }
        agg
    }

    /// One replica snapshot as JSON (a `per_replica` entry of the
    /// serve-metrics artifact).
    pub fn to_json(&self, wall: Duration) -> Json {
        Json::obj()
            .set("submitted", self.submitted)
            .set("responses", self.completed)
            .set("requests_finished", self.requests_finished)
            .set("rejected", self.rejected)
            .set("cancelled", self.cancelled)
            .set("expired", self.deadline_expired)
            .set("prefill_tokens", self.prefill_tokens)
            .set("decode_tokens", self.decode_tokens)
            .set("adopted_tokens", self.adopted_tokens)
            .set("preemptions", self.preemptions)
            .set("spec", spec_json(self))
            .set("cow_pages", self.cow_pages)
            .set("pages_in_use", self.pages_in_use)
            .set("pages_peak", self.pages_peak)
            .set("queue_depth", self.queue_depth)
            .set("queue_depth_peak", self.queue_depth_peak)
            .set("decode_tok_per_s", self.throughput(wall))
            .set("ttft_ms", latency_json(self, true))
            .set("total_ms", latency_json(self, false))
    }

    pub fn render(&self, wall: Duration) -> String {
        format!(
            "requests: {} submitted, {} completed, {} rejected, {} cancelled, \
             {} expired (queue depth {}, peak {})\n\
             tokens:   {} prefill, {} decode ({:.1} tok/s decode)\n\
             paged-kv: {}/{} pages in use (peak {}, {} free), {} adopted tokens, \
             prefix hit rate {:.0}%, {} tree evictions, {} cow copies, preemptions: {}\n\
             spec:     {} drafted, {} accepted ({:.0}% accept rate), \
             {} rollback pages\n\
             ttft:     p50 {:?}  p95 {:?}\n\
             e2e:      p50 {:?}  p95 {:?}",
            self.submitted,
            self.completed,
            self.rejected,
            self.cancelled,
            self.deadline_expired,
            self.queue_depth,
            self.queue_depth_peak,
            self.prefill_tokens,
            self.decode_tokens,
            self.throughput(wall),
            self.pages_in_use,
            self.page_budget,
            self.pages_peak,
            self.pages_free,
            self.adopted_tokens,
            self.prefix_hit_rate() * 100.0,
            self.prefix_evicted_pages,
            self.cow_pages,
            self.preemptions,
            self.spec_drafted,
            self.spec_accepted,
            self.draft_accept_rate() * 100.0,
            self.spec_rollback_pages,
            self.ttft_percentile(0.50).unwrap_or_default(),
            self.ttft_percentile(0.95).unwrap_or_default(),
            self.total_percentile(0.50).unwrap_or_default(),
            self.total_percentile(0.95).unwrap_or_default(),
        )
    }
}

/// Server-level admission counters. Requests the front-end rejects
/// (queue full, server stopped, invalid params) never reach an engine,
/// so the engine's [`Metrics`] can't count them — the server does.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// Submission attempts (accepted + every rejection class).
    pub submitted: u64,
    pub accepted: u64,
    pub queue_full: u64,
    pub server_stopped: u64,
    pub invalid_params: u64,
    /// Pinned submissions bounced because their replica was mid-respawn.
    pub replica_restarting: u64,
    /// Degraded-mode supervision counters. A replica death bumps
    /// `replica_restarts`; each orphaned in-flight request bumps
    /// `requeued` when it is rescheduled and `retries` on every replay
    /// attempt; a request whose retry budget ran out (or whose pinned
    /// replica could not be restarted) bumps `replica_lost` and retires
    /// with a synthetic [`FinishReason::ReplicaLost`](super::request::
    /// FinishReason) response.
    pub replica_restarts: u64,
    pub requeued: u64,
    pub retries: u64,
    pub replica_lost: u64,
}

/// The `serve-metrics.json` artifact: server-level admission counters
/// + the fleet aggregate + per-replica snapshots. After a
/// `Server::drain()` (no requests in flight) the exported counters
/// satisfy the request-granular identity — extended in schema `/2`
/// with the degraded-mode term:
/// `completed + rejected + cancelled + expired + replica_lost == submitted`.
pub fn serve_metrics_json(stats: &ServerStats, replicas: &[Metrics], wall: Duration) -> Json {
    let agg = Metrics::aggregate(replicas);
    let rejected = stats.queue_full
        + stats.server_stopped
        + stats.invalid_params
        + stats.replica_restarting
        + agg.rejected;
    Json::obj()
        .set("schema", "ptqtp-serve-metrics/2")
        .set("submitted", stats.submitted)
        .set("accepted", stats.accepted)
        .set("rejected", rejected)
        .set("queue_full", stats.queue_full)
        .set("server_stopped", stats.server_stopped)
        .set("invalid_params", stats.invalid_params)
        .set("replica_restarting", stats.replica_restarting)
        .set("completed", agg.requests_finished)
        .set("cancelled", agg.cancelled)
        .set("expired", agg.deadline_expired)
        .set("replica_restarts", stats.replica_restarts)
        .set("requeued", stats.requeued)
        .set("retries", stats.retries)
        .set("replica_lost", stats.replica_lost)
        .set("responses", agg.completed)
        .set("prefill_tokens", agg.prefill_tokens)
        .set("decode_tokens", agg.decode_tokens)
        .set("adopted_tokens", agg.adopted_tokens)
        .set("preemptions", agg.preemptions)
        .set("spec", spec_json(&agg))
        .set("queue_depth_peak", agg.queue_depth_peak)
        .set("wall_ms", wall.as_secs_f64() * 1e3)
        .set("decode_tok_per_s", agg.throughput(wall))
        .set("ttft_ms", latency_json(&agg, true))
        .set("total_ms", latency_json(&agg, false))
        .set(
            "per_replica",
            Json::Arr(replicas.iter().map(|m| m.to_json(wall)).collect()),
        )
}

/// Speculative-decoding counters block (`drafted` / `accepted` here
/// are draft tokens; the artifact's top-level `accepted` remains the
/// admission counter).
fn spec_json(m: &Metrics) -> Json {
    Json::obj()
        .set("drafted", m.spec_drafted)
        .set("accepted", m.spec_accepted)
        .set("draft_accept_rate", m.draft_accept_rate())
        .set("spec_rollback_pages", m.spec_rollback_pages)
}

/// `{p50_ms, p95_ms, histogram}` for one latency dimension.
fn latency_json(m: &Metrics, ttft: bool) -> Json {
    let (p50, p95, hist) = if ttft {
        (m.ttft_percentile(0.50), m.ttft_percentile(0.95), &m.ttft_hist)
    } else {
        (m.total_percentile(0.50), m.total_percentile(0.95), &m.total_hist)
    };
    let ms = |d: Option<Duration>| d.unwrap_or_default().as_secs_f64() * 1e3;
    Json::obj()
        .set("p50_ms", ms(p50))
        .set("p95_ms", ms(p95))
        .set("histogram", hist.to_json())
}

fn percentile(samples: &[Duration], p: f64) -> Option<Duration> {
    if samples.is_empty() {
        return None;
    }
    let mut v: Vec<Duration> = samples.to_vec();
    v.sort_unstable();
    let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
    Some(v[idx])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    fn resp(ms: u64) -> Response {
        Response {
            id: 0,
            sample: 0,
            tokens: vec![1],
            finish: FinishReason::Length,
            ttft: Duration::from_millis(ms),
            total: Duration::from_millis(ms * 2),
            prompt_len: 1,
        }
    }

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        for ms in [10u64, 20, 30, 40, 100] {
            m.record_response(&resp(ms));
        }
        let p50 = m.ttft_percentile(0.5).unwrap();
        let p95 = m.ttft_percentile(0.95).unwrap();
        assert!(p50 <= p95);
        assert_eq!(m.completed, 5);
        assert_eq!(m.ttft_hist.samples(), 5);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert!(m.ttft_percentile(0.5).is_none());
        assert_eq!(m.throughput(Duration::from_secs(1)), 0.0);
        let s = m.render(Duration::from_secs(1));
        assert!(s.contains("0 submitted"));
        assert!(s.contains("preemptions: 0"));
    }

    #[test]
    fn paged_counters_render() {
        let mut m = Metrics::default();
        m.prefix_lookups = 4;
        m.prefix_hits = 3;
        m.adopted_tokens = 192;
        m.preemptions = 2;
        m.pages_in_use = 5;
        m.page_budget = 8;
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-12);
        let s = m.render(Duration::from_secs(1));
        assert!(s.contains("5/8 pages in use"));
        assert!(s.contains("192 adopted tokens"));
        assert!(s.contains("prefix hit rate 75%"));
        assert!(s.contains("preemptions: 2"));
    }

    #[test]
    fn lifecycle_counters_render() {
        let mut m = Metrics::default();
        m.cancelled = 3;
        m.deadline_expired = 1;
        m.queue_depth_peak = 7;
        let s = m.render(Duration::from_secs(1));
        assert!(s.contains("3 cancelled"));
        assert!(s.contains("1 expired"));
        assert!(s.contains("peak 7"));
    }

    #[test]
    fn throughput_math() {
        let mut m = Metrics::default();
        m.decode_tokens = 100;
        assert!((m.throughput(Duration::from_secs(2)) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_buckets_and_merge() {
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_millis(0)); // -> bound 1
        h.record(Duration::from_millis(1)); // inclusive upper bound
        h.record(Duration::from_millis(3)); // -> bound 5
        h.record(Duration::from_secs(60)); // -> overflow
        assert_eq!(h.samples(), 4);
        assert_eq!(h.counts()[0], 2);
        assert_eq!(h.counts()[2], 1);
        assert_eq!(h.counts()[N_BUCKETS - 1], 1);
        let mut other = LatencyHistogram::default();
        other.record(Duration::from_millis(3));
        h.merge(&other);
        assert_eq!(h.counts()[2], 2);
        assert_eq!(h.samples(), 5);
    }

    #[test]
    fn spec_counters_merge_render_and_export() {
        let mut a = Metrics::default();
        a.spec_drafted = 8;
        a.spec_accepted = 6;
        a.spec_rollback_pages = 2;
        let mut b = Metrics::default();
        b.spec_drafted = 2;
        b.spec_accepted = 0;
        a.merge_from(&b);
        assert_eq!(a.spec_drafted, 10);
        assert!((a.draft_accept_rate() - 0.6).abs() < 1e-12);
        assert_eq!(Metrics::default().draft_accept_rate(), 0.0, "no drafts, no rate");
        let s = a.render(Duration::from_secs(1));
        assert!(s.contains("10 drafted"));
        assert!(s.contains("6 accepted (60% accept rate)"));
        assert!(s.contains("2 rollback pages"));
        // the artifact carries the counters in a nested block so the
        // top-level admission `accepted` key is undisturbed
        let j = serve_metrics_json(&ServerStats::default(), &[a], Duration::from_secs(1));
        let j = Json::parse(&j.pretty()).unwrap();
        let spec = j.get("spec").expect("spec block");
        assert_eq!(spec.req_f64("drafted").unwrap() as u64, 10);
        assert_eq!(spec.req_f64("accepted").unwrap() as u64, 6);
        assert!((spec.req_f64("draft_accept_rate").unwrap() - 0.6).abs() < 1e-9);
        assert_eq!(spec.req_f64("spec_rollback_pages").unwrap() as u64, 2);
        let replica = &j.get("per_replica").unwrap().as_arr().unwrap()[0];
        assert!(replica.get("spec").is_some(), "per-replica spec block");
    }

    #[test]
    fn serve_metrics_json_identity_and_roundtrip() {
        let mut a = Metrics::default();
        a.submitted = 3;
        a.requests_finished = 3;
        for ms in [5u64, 40, 900] {
            a.record_response(&resp(ms));
        }
        let mut b = Metrics::default();
        b.submitted = 2;
        b.requests_finished = 1;
        b.cancelled = 1;
        b.record_response(&resp(10));
        let stats = ServerStats {
            submitted: 8,
            accepted: 6,
            queue_full: 2,
            replica_restarts: 1,
            requeued: 1,
            retries: 2,
            replica_lost: 1,
            ..ServerStats::default()
        };
        let j = serve_metrics_json(&stats, &[a, b], Duration::from_secs(1));
        // round-trip through the hand-rolled parser, as CI will
        let j = Json::parse(&j.pretty()).unwrap();
        assert_eq!(j.req_str("schema").unwrap(), "ptqtp-serve-metrics/2");
        let get = |k: &str| j.req_f64(k).unwrap() as u64;
        assert_eq!(
            get("completed")
                + get("rejected")
                + get("cancelled")
                + get("expired")
                + get("replica_lost"),
            get("submitted"),
            "extended request-granular identity"
        );
        assert_eq!(get("replica_restarts"), 1);
        assert_eq!(get("requeued"), 1);
        assert_eq!(get("retries"), 2);
        assert_eq!(get("responses"), 4);
        assert_eq!(j.get("per_replica").unwrap().as_arr().unwrap().len(), 2);
        let ttft = j.get("ttft_ms").unwrap();
        assert!(ttft.req_f64("p95_ms").unwrap() >= ttft.req_f64("p50_ms").unwrap());
        let counts: f64 = ttft
            .get("histogram")
            .unwrap()
            .get("counts")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|c| c.as_f64().unwrap())
            .sum();
        assert_eq!(counts as u64, 4, "every response landed in a bucket");
    }
}

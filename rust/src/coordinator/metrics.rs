//! Serving metrics: counters + latency reservoirs, rendered for the
//! `ptqtp serve --report` output and the Table 5/6-style benches.

use super::request::Response;
use std::time::Duration;

/// Engine-level metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    /// Completed responses retained for percentile queries (bounded).
    pub finished: Vec<Response>,
    ttft_samples: Vec<Duration>,
    total_samples: Vec<Duration>,
}

const RESERVOIR: usize = 4096;

impl Metrics {
    pub fn record_response(&mut self, r: &Response) {
        self.completed += 1;
        if self.ttft_samples.len() < RESERVOIR {
            self.ttft_samples.push(r.ttft);
            self.total_samples.push(r.total);
        }
        if self.finished.len() < RESERVOIR {
            self.finished.push(r.clone());
        }
    }

    pub fn ttft_percentile(&self, p: f64) -> Option<Duration> {
        percentile(&self.ttft_samples, p)
    }

    pub fn total_percentile(&self, p: f64) -> Option<Duration> {
        percentile(&self.total_samples, p)
    }

    /// Tokens/second over a wall-clock window.
    pub fn throughput(&self, wall: Duration) -> f64 {
        self.decode_tokens as f64 / wall.as_secs_f64().max(1e-9)
    }

    pub fn render(&self, wall: Duration) -> String {
        format!(
            "requests: {} submitted, {} completed, {} rejected\n\
             tokens:   {} prefill, {} decode ({:.1} tok/s decode)\n\
             ttft:     p50 {:?}  p95 {:?}\n\
             e2e:      p50 {:?}  p95 {:?}",
            self.submitted,
            self.completed,
            self.rejected,
            self.prefill_tokens,
            self.decode_tokens,
            self.throughput(wall),
            self.ttft_percentile(0.50).unwrap_or_default(),
            self.ttft_percentile(0.95).unwrap_or_default(),
            self.total_percentile(0.50).unwrap_or_default(),
            self.total_percentile(0.95).unwrap_or_default(),
        )
    }
}

fn percentile(samples: &[Duration], p: f64) -> Option<Duration> {
    if samples.is_empty() {
        return None;
    }
    let mut v: Vec<Duration> = samples.to_vec();
    v.sort_unstable();
    let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
    Some(v[idx])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    fn resp(ms: u64) -> Response {
        Response {
            id: 0,
            tokens: vec![1],
            finish: FinishReason::Length,
            ttft: Duration::from_millis(ms),
            total: Duration::from_millis(ms * 2),
            prompt_len: 1,
        }
    }

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        for ms in [10u64, 20, 30, 40, 100] {
            m.record_response(&resp(ms));
        }
        let p50 = m.ttft_percentile(0.5).unwrap();
        let p95 = m.ttft_percentile(0.95).unwrap();
        assert!(p50 <= p95);
        assert_eq!(m.completed, 5);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert!(m.ttft_percentile(0.5).is_none());
        assert_eq!(m.throughput(Duration::from_secs(1)), 0.0);
        let s = m.render(Duration::from_secs(1));
        assert!(s.contains("0 submitted"));
    }

    #[test]
    fn throughput_math() {
        let mut m = Metrics::default();
        m.decode_tokens = 100;
        assert!((m.throughput(Duration::from_secs(2)) - 50.0).abs() < 1e-9);
    }
}

//! Grouped-query attention with KV cache — the module Table 6
//! benchmarks (`LlamaAttention` latency, FP16 vs PTQTP).
//!
//! Two entry points: [`Attention::decode`] is the classic one-token
//! path; [`Attention::decode_rows`] is the fused serving path — it
//! processes a whole [`ForwardBatch`]'s rows at once, where each row
//! carries its own position and its own sequence's KV cache, so
//! prefill chunks and decode tokens of many sequences share one QKV
//! projection over the stacked activations.
//!
//! The score/softmax/V-sum stage runs on the tiered head-major kernels
//! of [`super::attn_kernels`]: SIMD lanes across cached positions for
//! the scores, head-dim lanes for the V-sum, and [`Pool`] threading
//! across whole (row, head) output spans — every tier bitwise `==` the
//! scalar [`Attention::attend_one`] reference (the same parity
//! discipline as `ternary::simd`), so dispatch is purely a speed
//! decision.
//!
//! [`ForwardBatch`]: super::batch::ForwardBatch

use super::attn_kernels;
use super::batch::ensure_shape;
use super::kv::KvCache;
use super::linear::QuantLinear;
use super::rope::Rope;
use crate::tensor::ops::softmax_inplace;
use crate::tensor::Matrix;
use crate::ternary::gemm::GemmScratch;
use crate::ternary::simd;
use crate::threads::{run_spans, worth_parallel, Pool, SendPtr};

/// Attention body for one (query-row, head) over a **paged** KV chain:
/// per page [`attn_kernels::scores_into`] writes that page's slice of
/// the full score buffer, one softmax runs over the whole buffer, then
/// per page (ascending) [`attn_kernels::vsum_into`] folds into `out`.
/// Every score is an independent dot and the V-sum folds positions in
/// ascending order across pages — bitwise [`attn_kernels::attend_head`]
/// over a contiguous block, for any page size (DESIGN.md §Paged-KV).
/// `out` (`hd` long) must be zeroed; `scores` is caller scratch.
#[allow(clippy::too_many_arguments)]
fn attend_head_paged(
    q: &[f32],
    cache: &KvCache,
    layer: usize,
    kvh: usize,
    t: usize,
    hd: usize,
    scale: f32,
    lanes: usize,
    scores: &mut Vec<f32>,
    out: &mut [f32],
) {
    scores.clear();
    scores.resize(t, 0.0);
    let mut base = 0;
    for (ks, _) in cache.page_streams(layer, kvh, t) {
        let fill = ks.len() / hd;
        attn_kernels::scores_into(q, ks, fill, hd, scale, lanes, &mut scores[base..base + fill]);
        base += fill;
    }
    softmax_inplace(scores);
    let mut base = 0;
    for (_, vs) in cache.page_streams(layer, kvh, t) {
        let fill = vs.len() / hd;
        attn_kernels::vsum_into(&scores[base..base + fill], vs, hd, lanes, out);
        base += fill;
    }
}

/// One attention block's projections.
#[derive(Clone, Debug)]
pub struct Attention {
    pub wq: QuantLinear,
    pub wk: QuantLinear,
    pub wv: QuantLinear,
    pub wo: QuantLinear,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

/// Reusable buffers for the batched attention pass. The pool and SIMD
/// flag of `gemm` also drive the attend stage ([`ForwardScratch`] sets
/// both through [`AttnScratch::set_pool`]/[`AttnScratch::set_simd`]).
///
/// [`ForwardScratch`]: super::batch::ForwardScratch
#[derive(Clone, Debug, Default)]
pub struct AttnScratch {
    pub(crate) q: Matrix,
    pub(crate) k: Matrix,
    pub(crate) v: Matrix,
    pub(crate) attn: Matrix,
    pub(crate) scores: Vec<f32>,
    pub(crate) gemm: GemmScratch,
    /// Per-lane score buffers for the head-parallel attend stage.
    lane_scores: Vec<Vec<f32>>,
    /// Per-row causal horizons (`positions[i] + 1`), rebuilt per pass.
    horizons: Vec<usize>,
    /// Attention lane-width override: `None` = auto (detected width
    /// when SIMD is on, scalar otherwise); `Some(1 | 4 | 8)` pins a
    /// width for A/B runs — output is bitwise identical either way.
    lanes: Option<usize>,
}

impl AttnScratch {
    /// Bind the worker pool driving the QKV/output projections *and*
    /// the head-parallel attend stage.
    pub fn set_pool(&mut self, pool: Pool) {
        self.gemm.pool = pool;
    }

    /// Toggle the SIMD tier for the projections and attention kernels
    /// (default: the process-wide `--simd`/`PTQTP_SIMD` mode). Output
    /// is bitwise identical either way — perf/debug knob only.
    pub fn set_simd(&mut self, on: bool) {
        self.gemm.simd = on;
    }

    /// Toggle the int8-activation tier for the QKV/output projections
    /// (the attend stage itself stays f32 — scores and V-sums read the
    /// KV cache, not ternary planes). Value-changing; off by default
    /// (DESIGN.md §Integer-Kernels).
    pub fn set_act_quant(&mut self, on: bool) {
        self.gemm.act_quant = on;
    }

    /// Pin the attention kernel lane width (see [`AttnScratch`] field
    /// docs); tests use this to force the portable tiers. Panics on
    /// widths without a kernel.
    pub fn set_lanes(&mut self, lanes: Option<usize>) {
        if let Some(l) = lanes {
            assert!(matches!(l, 1 | 4 | 8), "attention lane width must be 1, 4, or 8 (got {l})");
        }
        self.lanes = lanes;
    }

    fn resolved_lanes(&self) -> usize {
        self.lanes.unwrap_or_else(|| simd::lanes_for(self.gemm.simd))
    }
}

/// Reusable buffers for the one-token [`Attention::decode_with`] path —
/// the same caller-owned pattern as [`GemmScratch`]: a long-context
/// decode loop holds one across steps, so the per-step q/k/v, head
/// accumulator, and score buffers stop allocating per token. Carries
/// its own pool/SIMD knobs so the single-row decode path reaches the
/// same tiered attend stage as the batched one.
#[derive(Clone, Debug)]
pub struct DecodeScratch {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    scores: Vec<f32>,
    lane_scores: Vec<Vec<f32>>,
    pool: Pool,
    simd: bool,
    lanes: Option<usize>,
    /// Int8-activation tier for the QKV/O projections (value-changing,
    /// off by default — DESIGN.md §Integer-Kernels).
    act_quant: bool,
    /// Quantized-activation scratch for the int tier.
    int_act: crate::ternary::int_act::IntActScratch,
}

impl Default for DecodeScratch {
    fn default() -> DecodeScratch {
        DecodeScratch {
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            attn: Vec::new(),
            scores: Vec::new(),
            lane_scores: Vec::new(),
            pool: Pool::default(),
            simd: simd::enabled(),
            lanes: None,
            act_quant: false,
            int_act: Default::default(),
        }
    }
}

impl DecodeScratch {
    /// Run the attend stage on `pool`'s lanes (whole-head spans;
    /// bit-identical for any thread count).
    pub fn set_pool(&mut self, pool: Pool) {
        self.pool = pool;
    }

    /// Toggle the SIMD attention kernels (bitwise-identical output).
    pub fn set_simd(&mut self, on: bool) {
        self.simd = on;
    }

    /// Toggle the int8-activation tier for the QKV/O projections
    /// (value-changing; `==`-exact to the batched int-tier paths).
    pub fn set_act_quant(&mut self, on: bool) {
        self.act_quant = on;
    }

    /// Pin the attention lane width (tests/benches). Panics on widths
    /// without a kernel.
    pub fn set_lanes(&mut self, lanes: Option<usize>) {
        if let Some(l) = lanes {
            assert!(matches!(l, 1 | 4 | 8), "attention lane width must be 1, 4, or 8 (got {l})");
        }
        self.lanes = lanes;
    }

    fn resolved_lanes(&self) -> usize {
        self.lanes.unwrap_or_else(|| simd::lanes_for(self.simd))
    }
}

impl Attention {
    /// Decode one token: `x` is the normed hidden state (d_model);
    /// appends this position's K/V to `cache[layer]` and returns the
    /// attention output (d_model). `pos` = index of this token.
    ///
    /// Allocates a fresh [`DecodeScratch`] per call; loops should hold
    /// a scratch and call [`Attention::decode_with`].
    pub fn decode(
        &self,
        x: &[f32],
        rope: &Rope,
        cache: &mut KvCache,
        layer: usize,
        pos: usize,
        out: &mut [f32],
    ) {
        let mut scratch = DecodeScratch::default();
        self.decode_with(x, rope, cache, layer, pos, &mut scratch, out);
    }

    /// [`Attention::decode`] over caller-owned scratch: zero per-token
    /// heap allocation in steady state, bit-identical output (the
    /// buffers are resized/zeroed to exactly the states the allocating
    /// path starts from).
    #[allow(clippy::too_many_arguments)]
    pub fn decode_with(
        &self,
        x: &[f32],
        rope: &Rope,
        cache: &mut KvCache,
        layer: usize,
        pos: usize,
        scratch: &mut DecodeScratch,
        out: &mut [f32],
    ) {
        let hd = self.head_dim;
        let q_dim = self.n_heads * hd;
        let kv_dim = self.n_kv_heads * hd;
        scratch.q.resize(q_dim, 0.0);
        scratch.k.resize(kv_dim, 0.0);
        scratch.v.resize(kv_dim, 0.0);
        self.wq.forward_vec_act(x, &mut scratch.q, scratch.act_quant, &mut scratch.int_act);
        self.wk.forward_vec_act(x, &mut scratch.k, scratch.act_quant, &mut scratch.int_act);
        self.wv.forward_vec_act(x, &mut scratch.v, scratch.act_quant, &mut scratch.int_act);
        rope.apply_heads(&mut scratch.q, pos);
        rope.apply_heads(&mut scratch.k, pos);
        cache.append(layer, &scratch.k, &scratch.v);

        let t = cache.staged_len(layer); // cached positions incl. current
        // attend_head accumulates into its output: zero the head buffer
        scratch.attn.clear();
        scratch.attn.resize(q_dim, 0.0);
        let lanes = scratch.resolved_lanes();
        let pool = scratch.pool.clone();
        let ts = [t];
        let cache_of = [0usize];
        let caches = [&mut *cache];
        let s = &mut *scratch;
        self.attend_stack(
            1,
            &s.q,
            &ts,
            &cache_of,
            &caches,
            layer,
            lanes,
            &pool,
            &mut s.scores,
            &mut s.lane_scores,
            &mut s.attn,
        );
        self.wo.forward_vec_act(&scratch.attn, out, scratch.act_quant, &mut scratch.int_act);
    }

    /// Scalar reference: score/softmax/weighted-sum for one query row
    /// over the first `t` cached positions — the numerics anchor every
    /// tiered path (SIMD lanes, threads) must match bitwise. `out` must
    /// be zeroed (`q_dim` long).
    pub fn attend_one(
        &self,
        q: &[f32],
        cache: &KvCache,
        layer: usize,
        t: usize,
        scores: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let hd = self.head_dim;
        let scale = 1.0 / (hd as f32).sqrt();
        let group = self.n_heads / self.n_kv_heads;
        for h in 0..self.n_heads {
            let kvh = h / group;
            attend_head_paged(
                &q[h * hd..(h + 1) * hd],
                cache,
                layer,
                kvh,
                t,
                hd,
                scale,
                1,
                scores,
                &mut out[h * hd..(h + 1) * hd],
            );
        }
    }

    /// Tiered attend stage over a stack of already-projected,
    /// already-roped query rows: row `i` of `q` attends over the first
    /// `ts[i]` cached positions of `caches[cache_of[i]]` at `layer`
    /// (the caches are only read). Lane width and pool come from
    /// `scratch`; output is bitwise the per-row
    /// [`Attention::attend_one`] for every configuration. Public so
    /// the attention bench and parity tests can race the tiers
    /// directly against the scalar reference.
    #[allow(clippy::too_many_arguments)]
    pub fn attend_rows(
        &self,
        q: &Matrix,
        ts: &[usize],
        cache_of: &[usize],
        caches: &[&mut KvCache],
        layer: usize,
        scratch: &mut AttnScratch,
        out: &mut Matrix,
    ) {
        let q_dim = self.n_heads * self.head_dim;
        debug_assert_eq!(q.cols, q_dim);
        debug_assert_eq!(ts.len(), q.rows);
        debug_assert_eq!(cache_of.len(), q.rows);
        ensure_shape(out, q.rows, q_dim);
        let lanes = scratch.resolved_lanes();
        let pool = scratch.gemm.pool.clone();
        self.attend_stack(
            q.rows,
            &q.data,
            ts,
            cache_of,
            caches,
            layer,
            lanes,
            &pool,
            &mut scratch.scores,
            &mut scratch.lane_scores,
            &mut out.data,
        );
    }

    /// The one tiered attend body behind every path: items = (row,
    /// head) pairs; threaded runs partition items into contiguous
    /// whole-head output spans via [`run_spans`] (each span a multiple
    /// of `head_dim`), every item computed in full by one lane with the
    /// scalar fold order — so threaded × SIMD output is bitwise the
    /// sequential scalar sweep. `caches` is a shared (read-only) view.
    #[allow(clippy::too_many_arguments)]
    fn attend_stack(
        &self,
        n: usize,
        q_data: &[f32],
        ts: &[usize],
        cache_of: &[usize],
        caches: &[&mut KvCache],
        layer: usize,
        lanes: usize,
        pool: &Pool,
        scores: &mut Vec<f32>,
        lane_scores: &mut Vec<Vec<f32>>,
        out: &mut [f32],
    ) {
        let hd = self.head_dim;
        let q_dim = self.n_heads * hd;
        debug_assert!(q_data.len() >= n * q_dim && out.len() >= n * q_dim);
        let group = self.n_heads / self.n_kv_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let items = n * self.n_heads;
        let max_t = ts.iter().copied().max().unwrap_or(0);
        if pool.threads() <= 1 || !worth_parallel(items * hd, max_t) {
            for i in 0..n {
                let t = ts[i];
                let cache: &KvCache = &*caches[cache_of[i]];
                for h in 0..self.n_heads {
                    let kvh = h / group;
                    attend_head_paged(
                        &q_data[i * q_dim + h * hd..i * q_dim + (h + 1) * hd],
                        cache,
                        layer,
                        kvh,
                        t,
                        hd,
                        scale,
                        lanes,
                        scores,
                        &mut out[i * q_dim + h * hd..i * q_dim + (h + 1) * hd],
                    );
                }
            }
            return;
        }
        if lane_scores.len() < pool.threads() {
            lane_scores.resize_with(pool.threads(), Vec::new);
        }
        let ls = SendPtr(lane_scores.as_mut_ptr());
        run_spans(pool, items, hd, &mut out[..items * hd], |lane, item_range, span| {
            // SAFETY: one score buffer per lane (resized above); the
            // vec outlives the call because the leader blocks in `run`.
            let scores = unsafe { &mut *ls.get().add(lane) };
            for (off, item) in item_range.enumerate() {
                let i = item / self.n_heads;
                let h = item % self.n_heads;
                let t = ts[i];
                let cache: &KvCache = &*caches[cache_of[i]];
                let kvh = h / group;
                attend_head_paged(
                    &q_data[i * q_dim + h * hd..i * q_dim + (h + 1) * hd],
                    cache,
                    layer,
                    kvh,
                    t,
                    hd,
                    scale,
                    lanes,
                    scores,
                    &mut span[off * hd..(off + 1) * hd],
                );
            }
        });
    }

    /// Fused multi-position attention: row `i` of `normed` is one token
    /// at `positions[i]` belonging to `caches[cache_of[i]]`. All rows'
    /// K/V are appended (uncommitted) to their caches before any score
    /// is computed, and row `i` attends over exactly the first
    /// `positions[i] + 1` cached positions — so a prefill chunk sees
    /// its own earlier rows (causal) but never later ones.
    ///
    /// Per row this is bit-identical to [`Attention::decode`]: the QKV
    /// and output projections run the row-exact batched kernels, and
    /// the attend stage runs the tiered head-major kernels whose every
    /// configuration replays the scalar operation order.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_rows(
        &self,
        normed: &Matrix,
        positions: &[usize],
        cache_of: &[usize],
        rope: &Rope,
        caches: &mut [&mut KvCache],
        layer: usize,
        scratch: &mut AttnScratch,
        out: &mut Matrix,
    ) {
        let n = normed.rows;
        debug_assert_eq!(positions.len(), n);
        debug_assert_eq!(cache_of.len(), n);
        let hd = self.head_dim;
        let q_dim = self.n_heads * hd;
        let kv_dim = self.n_kv_heads * hd;
        ensure_shape(&mut scratch.q, n, q_dim);
        ensure_shape(&mut scratch.k, n, kv_dim);
        ensure_shape(&mut scratch.v, n, kv_dim);
        ensure_shape(&mut scratch.attn, n, q_dim);
        self.wq.forward_rows_into(normed, &mut scratch.q, &mut scratch.gemm);
        self.wk.forward_rows_into(normed, &mut scratch.k, &mut scratch.gemm);
        self.wv.forward_rows_into(normed, &mut scratch.v, &mut scratch.gemm);
        for i in 0..n {
            rope.apply_heads(scratch.q.row_mut(i), positions[i]);
            rope.apply_heads(scratch.k.row_mut(i), positions[i]);
        }
        // stage every row's K/V first so intra-chunk attention sees them
        for i in 0..n {
            let cache = &mut *caches[cache_of[i]];
            cache.append(layer, scratch.k.row(i), scratch.v.row(i));
            debug_assert_eq!(
                cache.staged_len(layer),
                positions[i] + 1,
                "batch rows for one cache must be contiguous with ascending positions"
            );
        }
        scratch.horizons.clear();
        scratch.horizons.extend(positions.iter().map(|&p| p + 1));
        let lanes = scratch.resolved_lanes();
        let pool = scratch.gemm.pool.clone();
        let caches: &[&mut KvCache] = caches; // read-only from here
        let s = &mut *scratch;
        self.attend_stack(
            n,
            &s.q.data,
            &s.horizons,
            cache_of,
            caches,
            layer,
            lanes,
            &pool,
            &mut s.scores,
            &mut s.lane_scores,
            &mut s.attn.data,
        );
        self.wo.forward_rows_into(&scratch.attn, out, &mut scratch.gemm);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::Matrix;

    fn make_attn(d: usize, heads: usize, kv: usize, seed: u64) -> Attention {
        let mut rng = Rng::new(seed);
        let hd = d / heads;
        Attention {
            wq: QuantLinear::dense(Matrix::randn(heads * hd, d, 0.1, &mut rng)),
            wk: QuantLinear::dense(Matrix::randn(kv * hd, d, 0.1, &mut rng)),
            wv: QuantLinear::dense(Matrix::randn(kv * hd, d, 0.1, &mut rng)),
            wo: QuantLinear::dense(Matrix::randn(d, heads * hd, 0.1, &mut rng)),
            n_heads: heads,
            n_kv_heads: kv,
            head_dim: hd,
        }
    }

    #[test]
    fn decode_shapes_and_cache_growth() {
        let attn = make_attn(32, 4, 2, 1);
        let rope = Rope::new(8, 16, 10_000.0);
        let mut cache = KvCache::new(1, 2, 8, 16);
        let mut rng = Rng::new(2);
        for pos in 0..5 {
            let x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
            let mut out = vec![0.0; 32];
            attn.decode(&x, &rope, &mut cache, 0, pos, &mut out);
            cache.commit();
            assert!(out.iter().all(|v| v.is_finite()));
        }
        assert_eq!(cache.len(), 5);
    }

    #[test]
    fn first_token_attends_only_to_itself() {
        // with a single cached position, attention output = wo·v
        let attn = make_attn(16, 2, 2, 3);
        let rope = Rope::new(8, 8, 10_000.0);
        let mut cache = KvCache::new(1, 2, 8, 8);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let mut out = vec![0.0; 16];
        attn.decode(&x, &rope, &mut cache, 0, 0, &mut out);
        // reference: v then wo
        let mut v = vec![0.0; 16];
        attn.wv.forward_vec(&x, &mut v);
        let mut expect = vec![0.0; 16];
        attn.wo.forward_vec(&v, &mut expect);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn gqa_shares_kv_heads() {
        // n_heads=4, n_kv=1: all query heads read the same contiguous
        // K/V block; output must be finite and deterministic
        let attn = make_attn(32, 4, 1, 5);
        let rope = Rope::new(8, 8, 10_000.0);
        let mut c1 = KvCache::new(1, 1, 8, 8);
        let mut c2 = KvCache::new(1, 1, 8, 8);
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.1).sin()).collect();
        let mut o1 = vec![0.0; 32];
        let mut o2 = vec![0.0; 32];
        attn.decode(&x, &rope, &mut c1, 0, 0, &mut o1);
        attn.decode(&x, &rope, &mut c2, 0, 0, &mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn decode_with_reused_scratch_bit_identical_to_decode() {
        // one scratch across many positions (long-context decode
        // pattern) must equal the fresh-allocation path exactly
        let attn = make_attn(32, 4, 2, 17);
        let rope = Rope::new(8, 32, 10_000.0);
        let mut rng = Rng::new(18);
        let mut c_ref = KvCache::new(1, 2, 8, 32);
        let mut c_scr = KvCache::new(1, 2, 8, 32);
        let mut scratch = DecodeScratch::default();
        for pos in 0..12 {
            let x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
            let mut a = vec![0.0; 32];
            let mut b = vec![0.0; 32];
            attn.decode(&x, &rope, &mut c_ref, 0, pos, &mut a);
            c_ref.commit();
            attn.decode_with(&x, &rope, &mut c_scr, 0, pos, &mut scratch, &mut b);
            c_scr.commit();
            assert_eq!(a, b, "pos {pos}");
        }
        for kvh in 0..2 {
            assert_eq!(c_ref.keys(0, kvh), c_scr.keys(0, kvh));
            assert_eq!(c_ref.values(0, kvh), c_scr.values(0, kvh));
        }
    }

    #[test]
    fn decode_simd_threads_knobs_bit_identical() {
        // every (lanes, pool) configuration of the one-token path must
        // reproduce the scalar output exactly
        let attn = make_attn(32, 4, 2, 19);
        let rope = Rope::new(8, 32, 10_000.0);
        let mut rng = Rng::new(20);
        let xs: Vec<Vec<f32>> = (0..10)
            .map(|_| (0..32).map(|_| rng.normal()).collect())
            .collect();
        let run = |lanes: Option<usize>, simd: bool, threads: usize| {
            let mut cache = KvCache::new(1, 2, 8, 32);
            let mut scratch = DecodeScratch::default();
            scratch.set_simd(simd);
            scratch.set_lanes(lanes);
            scratch.set_pool(Pool::new(threads));
            let mut outs = Vec::new();
            for (pos, x) in xs.iter().enumerate() {
                let mut out = vec![0.0; 32];
                attn.decode_with(x, &rope, &mut cache, 0, pos, &mut scratch, &mut out);
                cache.commit();
                outs.push(out);
            }
            outs
        };
        let reference = run(Some(1), false, 1);
        for lanes in [None, Some(4), Some(8)] {
            for threads in [1usize, 2] {
                assert_eq!(
                    run(lanes, true, threads),
                    reference,
                    "lanes={lanes:?} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn decode_rows_bit_identical_to_sequential_decode() {
        // one fused call over a 4-token chunk == four sequential decodes
        let attn = make_attn(32, 4, 2, 7);
        let rope = Rope::new(8, 16, 10_000.0);
        let mut rng = Rng::new(8);
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..32).map(|_| rng.normal()).collect())
            .collect();

        // sequential reference
        let mut seq_cache = KvCache::new(1, 2, 8, 16);
        let mut expect = Vec::new();
        for (pos, x) in xs.iter().enumerate() {
            let mut out = vec![0.0; 32];
            attn.decode(x, &rope, &mut seq_cache, 0, pos, &mut out);
            seq_cache.commit();
            expect.push(out);
        }

        // fused chunk
        let mut cache = KvCache::new(1, 2, 8, 16);
        let mut normed = Matrix::zeros(4, 32);
        for (i, x) in xs.iter().enumerate() {
            normed.row_mut(i).copy_from_slice(x);
        }
        let mut scratch = AttnScratch::default();
        let mut out = Matrix::zeros(4, 32);
        let positions = [0, 1, 2, 3];
        let cache_of = [0usize; 4];
        attn.decode_rows(
            &normed, &positions, &cache_of, &rope, &mut [&mut cache], 0, &mut scratch, &mut out,
        );
        cache.commit_n(4);
        for i in 0..4 {
            assert_eq!(out.row(i), expect[i].as_slice(), "row {i}");
        }
        assert_eq!(cache.len(), 4);
        for kvh in 0..2 {
            assert_eq!(cache.keys(0, kvh), seq_cache.keys(0, kvh));
            assert_eq!(cache.values(0, kvh), seq_cache.values(0, kvh));
        }
    }

    #[test]
    fn decode_rows_multiple_sequences() {
        // two sequences at different positions in one fused call
        let attn = make_attn(16, 2, 2, 9);
        let rope = Rope::new(8, 8, 10_000.0);
        let mut rng = Rng::new(10);
        let x0: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let x1: Vec<f32> = (0..16).map(|_| rng.normal()).collect();

        // seq A already has one committed position
        let warm: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let mut ca = KvCache::new(1, 2, 8, 8);
        let mut warm_out = vec![0.0; 16];
        attn.decode(&warm, &rope, &mut ca, 0, 0, &mut warm_out);
        ca.commit();
        let mut cb = KvCache::new(1, 2, 8, 8);

        // sequential reference for both next tokens
        let mut ca_ref = ca.clone();
        let mut ea = vec![0.0; 16];
        attn.decode(&x0, &rope, &mut ca_ref, 0, 1, &mut ea);
        let mut cb_ref = cb.clone();
        let mut eb = vec![0.0; 16];
        attn.decode(&x1, &rope, &mut cb_ref, 0, 0, &mut eb);

        let mut normed = Matrix::zeros(2, 16);
        normed.row_mut(0).copy_from_slice(&x0);
        normed.row_mut(1).copy_from_slice(&x1);
        let mut scratch = AttnScratch::default();
        let mut out = Matrix::zeros(2, 16);
        attn.decode_rows(
            &normed,
            &[1, 0],
            &[0, 1],
            &rope,
            &mut [&mut ca, &mut cb],
            0,
            &mut scratch,
            &mut out,
        );
        assert_eq!(out.row(0), ea.as_slice());
        assert_eq!(out.row(1), eb.as_slice());
    }

    #[test]
    fn paged_attend_bit_identical_to_single_page() {
        // the same decode stream over a page_size-4 paged cache must be
        // bitwise the legacy single-page cache for every (lanes,
        // threads) configuration — ISSUE 6's core parity gate
        use super::super::kv::PageStore;
        let attn = make_attn(32, 4, 2, 23);
        let rope = Rope::new(8, 32, 10_000.0);
        let mut rng = Rng::new(24);
        let xs: Vec<Vec<f32>> = (0..11)
            .map(|_| (0..32).map(|_| rng.normal()).collect())
            .collect();
        let run = |paged: bool, lanes: Option<usize>, threads: usize| {
            let mut cache = if paged {
                let store = PageStore::for_geometry(1, 2, 8, 4, None);
                KvCache::paged(1, 2, 8, 32, 4, store)
            } else {
                KvCache::new(1, 2, 8, 32)
            };
            let mut scratch = DecodeScratch::default();
            scratch.set_simd(lanes != Some(1));
            scratch.set_lanes(lanes);
            scratch.set_pool(Pool::new(threads));
            let mut outs = Vec::new();
            for (pos, x) in xs.iter().enumerate() {
                let mut out = vec![0.0; 32];
                attn.decode_with(x, &rope, &mut cache, 0, pos, &mut scratch, &mut out);
                cache.commit();
                outs.push(out);
            }
            outs
        };
        let reference = run(false, Some(1), 1);
        for lanes in [Some(1), Some(4), Some(8), None] {
            for threads in [1usize, 2] {
                assert_eq!(
                    run(true, lanes, threads),
                    reference,
                    "paged lanes={lanes:?} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn attends_to_history() {
        // second token's output must depend on the first token's value
        let attn = make_attn(16, 2, 2, 6);
        let rope = Rope::new(8, 8, 10_000.0);
        let x0a: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let x0b: Vec<f32> = (0..16).map(|i| -(i as f32) * 0.1).collect();
        let x1: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).cos()).collect();
        let run = |x0: &[f32]| {
            let mut cache = KvCache::new(1, 2, 8, 8);
            let mut out = vec![0.0; 16];
            attn.decode(x0, &rope, &mut cache, 0, 0, &mut out);
            cache.commit();
            let mut out1 = vec![0.0; 16];
            attn.decode(&x1, &rope, &mut cache, 0, 1, &mut out1);
            out1
        };
        assert!(run(&x0a) != run(&x0b));
    }
}

//! Grouped-query attention with KV cache — the module Table 6
//! benchmarks (`LlamaAttention` latency, FP16 vs PTQTP).
//!
//! Two entry points: [`Attention::decode`] is the classic one-token
//! path (kept as the numerics reference); [`Attention::decode_rows`]
//! is the fused serving path — it processes a whole [`ForwardBatch`]'s
//! rows at once, where each row carries its own position and its own
//! sequence's KV cache, so prefill chunks and decode tokens of many
//! sequences share one QKV projection over the stacked activations.
//!
//! [`ForwardBatch`]: super::batch::ForwardBatch

use super::batch::ensure_shape;
use super::kv::KvCache;
use super::linear::QuantLinear;
use super::rope::Rope;
use crate::tensor::ops::softmax_inplace;
use crate::tensor::Matrix;
use crate::ternary::gemm::GemmScratch;

/// One attention block's projections.
#[derive(Clone, Debug)]
pub struct Attention {
    pub wq: QuantLinear,
    pub wk: QuantLinear,
    pub wv: QuantLinear,
    pub wo: QuantLinear,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

/// Reusable buffers for the batched attention pass.
#[derive(Clone, Debug, Default)]
pub struct AttnScratch {
    pub(crate) q: Matrix,
    pub(crate) k: Matrix,
    pub(crate) v: Matrix,
    pub(crate) attn: Matrix,
    pub(crate) scores: Vec<f32>,
    pub(crate) gemm: GemmScratch,
}

/// Reusable buffers for the one-token [`Attention::decode_with`] path —
/// the same caller-owned pattern as [`GemmScratch`]: a long-context
/// decode loop holds one across steps, so the per-step q/k/v, head
/// accumulator, and score buffers stop allocating per token.
#[derive(Clone, Debug, Default)]
pub struct DecodeScratch {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    scores: Vec<f32>,
}

impl Attention {
    /// Decode one token: `x` is the normed hidden state (d_model);
    /// appends this position's K/V to `cache[layer]` and returns the
    /// attention output (d_model). `pos` = index of this token.
    ///
    /// Allocates a fresh [`DecodeScratch`] per call (kept as the simple
    /// numerics-reference entry); loops should hold a scratch and call
    /// [`Attention::decode_with`].
    pub fn decode(
        &self,
        x: &[f32],
        rope: &Rope,
        cache: &mut KvCache,
        layer: usize,
        pos: usize,
        out: &mut [f32],
    ) {
        let mut scratch = DecodeScratch::default();
        self.decode_with(x, rope, cache, layer, pos, &mut scratch, out);
    }

    /// [`Attention::decode`] over caller-owned scratch: zero per-token
    /// heap allocation in steady state, bit-identical output (the
    /// buffers are resized/zeroed to exactly the states the allocating
    /// path starts from).
    #[allow(clippy::too_many_arguments)]
    pub fn decode_with(
        &self,
        x: &[f32],
        rope: &Rope,
        cache: &mut KvCache,
        layer: usize,
        pos: usize,
        scratch: &mut DecodeScratch,
        out: &mut [f32],
    ) {
        let hd = self.head_dim;
        let q_dim = self.n_heads * hd;
        let kv_dim = self.n_kv_heads * hd;
        scratch.q.resize(q_dim, 0.0);
        scratch.k.resize(kv_dim, 0.0);
        scratch.v.resize(kv_dim, 0.0);
        self.wq.forward_vec(x, &mut scratch.q);
        self.wk.forward_vec(x, &mut scratch.k);
        self.wv.forward_vec(x, &mut scratch.v);
        rope.apply_heads(&mut scratch.q, pos);
        rope.apply_heads(&mut scratch.k, pos);
        cache.append(layer, &scratch.k, &scratch.v);

        let keys = cache.keys(layer);
        let vals = cache.values(layer);
        let t = keys.len() / kv_dim; // cached positions incl. current
        // attend_one accumulates into its output: zero the head buffer
        scratch.attn.clear();
        scratch.attn.resize(q_dim, 0.0);
        self.attend_one(&scratch.q, keys, vals, t, &mut scratch.scores, &mut scratch.attn);
        self.wo.forward_vec(&scratch.attn, out);
    }

    /// Score/softmax/weighted-sum for one query row over `t` cached
    /// positions — the single numerics body shared by the per-token
    /// [`Attention::decode`] and the batched [`Attention::decode_rows`]
    /// paths, so fused/sequential parity cannot drift. `out` must be
    /// zeroed (`q_dim` long); `keys`/`vals` hold `t · kv_dim` values.
    fn attend_one(
        &self,
        q: &[f32],
        keys: &[f32],
        vals: &[f32],
        t: usize,
        scores: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let hd = self.head_dim;
        let kv_dim = self.n_kv_heads * hd;
        let scale = 1.0 / (hd as f32).sqrt();
        let group = self.n_heads / self.n_kv_heads;
        scores.clear();
        scores.resize(t, 0.0);
        for h in 0..self.n_heads {
            let kvh = h / group;
            let qh = &q[h * hd..(h + 1) * hd];
            for (ti, score) in scores.iter_mut().enumerate() {
                let kh = &keys[ti * kv_dim + kvh * hd..ti * kv_dim + (kvh + 1) * hd];
                *score = crate::tensor::ops::dot(qh, kh) * scale;
            }
            softmax_inplace(scores);
            let oh = &mut out[h * hd..(h + 1) * hd];
            for (ti, &p) in scores.iter().enumerate() {
                let vh = &vals[ti * kv_dim + kvh * hd..ti * kv_dim + (kvh + 1) * hd];
                for i in 0..hd {
                    oh[i] += p * vh[i];
                }
            }
        }
    }

    /// Fused multi-position attention: row `i` of `normed` is one token
    /// at `positions[i]` belonging to `caches[cache_of[i]]`. All rows'
    /// K/V are appended (uncommitted) to their caches before any score
    /// is computed, and row `i` attends over exactly the first
    /// `positions[i] + 1` cached positions — so a prefill chunk sees
    /// its own earlier rows (causal) but never later ones.
    ///
    /// Per row this is bit-identical to [`Attention::decode`]: the QKV
    /// and output projections run the row-exact batched kernels, and
    /// the score/softmax/weighted-sum loops mirror the decode path's
    /// operation order.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_rows(
        &self,
        normed: &Matrix,
        positions: &[usize],
        cache_of: &[usize],
        rope: &Rope,
        caches: &mut [&mut KvCache],
        layer: usize,
        scratch: &mut AttnScratch,
        out: &mut Matrix,
    ) {
        let n = normed.rows;
        debug_assert_eq!(positions.len(), n);
        debug_assert_eq!(cache_of.len(), n);
        let hd = self.head_dim;
        let q_dim = self.n_heads * hd;
        let kv_dim = self.n_kv_heads * hd;
        ensure_shape(&mut scratch.q, n, q_dim);
        ensure_shape(&mut scratch.k, n, kv_dim);
        ensure_shape(&mut scratch.v, n, kv_dim);
        ensure_shape(&mut scratch.attn, n, q_dim);
        self.wq.forward_rows_into(normed, &mut scratch.q, &mut scratch.gemm);
        self.wk.forward_rows_into(normed, &mut scratch.k, &mut scratch.gemm);
        self.wv.forward_rows_into(normed, &mut scratch.v, &mut scratch.gemm);
        for i in 0..n {
            rope.apply_heads(scratch.q.row_mut(i), positions[i]);
            rope.apply_heads(scratch.k.row_mut(i), positions[i]);
        }
        // stage every row's K/V first so intra-chunk attention sees them
        for i in 0..n {
            let cache = &mut *caches[cache_of[i]];
            cache.append(layer, scratch.k.row(i), scratch.v.row(i));
            debug_assert_eq!(
                cache.staged_len(layer),
                positions[i] + 1,
                "batch rows for one cache must be contiguous with ascending positions"
            );
        }
        for i in 0..n {
            let cache = &*caches[cache_of[i]];
            let t = positions[i] + 1; // causal horizon incl. this row
            let keys = &cache.keys(layer)[..t * kv_dim];
            let vals = &cache.values(layer)[..t * kv_dim];
            self.attend_one(
                scratch.q.row(i),
                keys,
                vals,
                t,
                &mut scratch.scores,
                scratch.attn.row_mut(i),
            );
        }
        self.wo.forward_rows_into(&scratch.attn, out, &mut scratch.gemm);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::Matrix;

    fn make_attn(d: usize, heads: usize, kv: usize, seed: u64) -> Attention {
        let mut rng = Rng::new(seed);
        let hd = d / heads;
        Attention {
            wq: QuantLinear::dense(Matrix::randn(heads * hd, d, 0.1, &mut rng)),
            wk: QuantLinear::dense(Matrix::randn(kv * hd, d, 0.1, &mut rng)),
            wv: QuantLinear::dense(Matrix::randn(kv * hd, d, 0.1, &mut rng)),
            wo: QuantLinear::dense(Matrix::randn(d, heads * hd, 0.1, &mut rng)),
            n_heads: heads,
            n_kv_heads: kv,
            head_dim: hd,
        }
    }

    #[test]
    fn decode_shapes_and_cache_growth() {
        let attn = make_attn(32, 4, 2, 1);
        let rope = Rope::new(8, 16, 10_000.0);
        let mut cache = KvCache::new(1, 16, 16);
        let mut rng = Rng::new(2);
        for pos in 0..5 {
            let x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
            let mut out = vec![0.0; 32];
            attn.decode(&x, &rope, &mut cache, 0, pos, &mut out);
            cache.commit();
            assert!(out.iter().all(|v| v.is_finite()));
        }
        assert_eq!(cache.len(), 5);
    }

    #[test]
    fn first_token_attends_only_to_itself() {
        // with a single cached position, attention output = wo·v
        let attn = make_attn(16, 2, 2, 3);
        let rope = Rope::new(8, 8, 10_000.0);
        let mut cache = KvCache::new(1, 16, 8);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let mut out = vec![0.0; 16];
        attn.decode(&x, &rope, &mut cache, 0, 0, &mut out);
        // reference: v then wo
        let mut v = vec![0.0; 16];
        attn.wv.forward_vec(&x, &mut v);
        let mut expect = vec![0.0; 16];
        attn.wo.forward_vec(&v, &mut expect);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn gqa_shares_kv_heads() {
        // n_heads=4, n_kv=1: all query heads read the same K/V stripe;
        // output must be finite and deterministic
        let attn = make_attn(32, 4, 1, 5);
        let rope = Rope::new(8, 8, 10_000.0);
        let mut c1 = KvCache::new(1, 8, 8);
        let mut c2 = KvCache::new(1, 8, 8);
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.1).sin()).collect();
        let mut o1 = vec![0.0; 32];
        let mut o2 = vec![0.0; 32];
        attn.decode(&x, &rope, &mut c1, 0, 0, &mut o1);
        attn.decode(&x, &rope, &mut c2, 0, 0, &mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn decode_with_reused_scratch_bit_identical_to_decode() {
        // one scratch across many positions (long-context decode
        // pattern) must equal the fresh-allocation path exactly
        let attn = make_attn(32, 4, 2, 17);
        let rope = Rope::new(8, 32, 10_000.0);
        let mut rng = Rng::new(18);
        let mut c_ref = KvCache::new(1, 16, 32);
        let mut c_scr = KvCache::new(1, 16, 32);
        let mut scratch = DecodeScratch::default();
        for pos in 0..12 {
            let x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
            let mut a = vec![0.0; 32];
            let mut b = vec![0.0; 32];
            attn.decode(&x, &rope, &mut c_ref, 0, pos, &mut a);
            c_ref.commit();
            attn.decode_with(&x, &rope, &mut c_scr, 0, pos, &mut scratch, &mut b);
            c_scr.commit();
            assert_eq!(a, b, "pos {pos}");
        }
        assert_eq!(c_ref.keys(0), c_scr.keys(0));
        assert_eq!(c_ref.values(0), c_scr.values(0));
    }

    #[test]
    fn decode_rows_bit_identical_to_sequential_decode() {
        // one fused call over a 4-token chunk == four sequential decodes
        let attn = make_attn(32, 4, 2, 7);
        let rope = Rope::new(8, 16, 10_000.0);
        let mut rng = Rng::new(8);
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..32).map(|_| rng.normal()).collect())
            .collect();

        // sequential reference
        let mut seq_cache = KvCache::new(1, 16, 16);
        let mut expect = Vec::new();
        for (pos, x) in xs.iter().enumerate() {
            let mut out = vec![0.0; 32];
            attn.decode(x, &rope, &mut seq_cache, 0, pos, &mut out);
            seq_cache.commit();
            expect.push(out);
        }

        // fused chunk
        let mut cache = KvCache::new(1, 16, 16);
        let mut normed = Matrix::zeros(4, 32);
        for (i, x) in xs.iter().enumerate() {
            normed.row_mut(i).copy_from_slice(x);
        }
        let mut scratch = AttnScratch::default();
        let mut out = Matrix::zeros(4, 32);
        let positions = [0, 1, 2, 3];
        let cache_of = [0usize; 4];
        attn.decode_rows(
            &normed, &positions, &cache_of, &rope, &mut [&mut cache], 0, &mut scratch, &mut out,
        );
        cache.commit_n(4);
        for i in 0..4 {
            assert_eq!(out.row(i), expect[i].as_slice(), "row {i}");
        }
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.keys(0), seq_cache.keys(0));
        assert_eq!(cache.values(0), seq_cache.values(0));
    }

    #[test]
    fn decode_rows_multiple_sequences() {
        // two sequences at different positions in one fused call
        let attn = make_attn(16, 2, 2, 9);
        let rope = Rope::new(8, 8, 10_000.0);
        let mut rng = Rng::new(10);
        let x0: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let x1: Vec<f32> = (0..16).map(|_| rng.normal()).collect();

        // seq A already has one committed position
        let warm: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let mut ca = KvCache::new(1, 16, 8);
        let mut warm_out = vec![0.0; 16];
        attn.decode(&warm, &rope, &mut ca, 0, 0, &mut warm_out);
        ca.commit();
        let mut cb = KvCache::new(1, 16, 8);

        // sequential reference for both next tokens
        let mut ca_ref = ca.clone();
        let mut ea = vec![0.0; 16];
        attn.decode(&x0, &rope, &mut ca_ref, 0, 1, &mut ea);
        let mut cb_ref = cb.clone();
        let mut eb = vec![0.0; 16];
        attn.decode(&x1, &rope, &mut cb_ref, 0, 0, &mut eb);

        let mut normed = Matrix::zeros(2, 16);
        normed.row_mut(0).copy_from_slice(&x0);
        normed.row_mut(1).copy_from_slice(&x1);
        let mut scratch = AttnScratch::default();
        let mut out = Matrix::zeros(2, 16);
        attn.decode_rows(
            &normed,
            &[1, 0],
            &[0, 1],
            &rope,
            &mut [&mut ca, &mut cb],
            0,
            &mut scratch,
            &mut out,
        );
        assert_eq!(out.row(0), ea.as_slice());
        assert_eq!(out.row(1), eb.as_slice());
    }

    #[test]
    fn attends_to_history() {
        // second token's output must depend on the first token's value
        let attn = make_attn(16, 2, 2, 6);
        let rope = Rope::new(8, 8, 10_000.0);
        let x0a: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let x0b: Vec<f32> = (0..16).map(|i| -(i as f32) * 0.1).collect();
        let x1: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).cos()).collect();
        let run = |x0: &[f32]| {
            let mut cache = KvCache::new(1, 16, 8);
            let mut out = vec![0.0; 16];
            attn.decode(x0, &rope, &mut cache, 0, 0, &mut out);
            cache.commit();
            let mut out1 = vec![0.0; 16];
            attn.decode(&x1, &rope, &mut cache, 0, 1, &mut out1);
            out1
        };
        assert!(run(&x0a) != run(&x0b));
    }
}

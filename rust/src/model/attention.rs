//! Grouped-query attention with KV cache (decode path) — the module
//! Table 6 benchmarks (`LlamaAttention` latency, FP16 vs PTQTP).

use super::kv::KvCache;
use super::linear::QuantLinear;
use super::rope::Rope;
use crate::tensor::ops::softmax_inplace;

/// One attention block's projections.
#[derive(Clone, Debug)]
pub struct Attention {
    pub wq: QuantLinear,
    pub wk: QuantLinear,
    pub wv: QuantLinear,
    pub wo: QuantLinear,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

impl Attention {
    /// Decode one token: `x` is the normed hidden state (d_model);
    /// appends this position's K/V to `cache[layer]` and returns the
    /// attention output (d_model). `pos` = index of this token.
    pub fn decode(
        &self,
        x: &[f32],
        rope: &Rope,
        cache: &mut KvCache,
        layer: usize,
        pos: usize,
        out: &mut [f32],
    ) {
        let hd = self.head_dim;
        let q_dim = self.n_heads * hd;
        let kv_dim = self.n_kv_heads * hd;
        let mut q = vec![0.0f32; q_dim];
        let mut k = vec![0.0f32; kv_dim];
        let mut v = vec![0.0f32; kv_dim];
        self.wq.forward_vec(x, &mut q);
        self.wk.forward_vec(x, &mut k);
        self.wv.forward_vec(x, &mut v);
        rope.apply_heads(&mut q, pos);
        rope.apply_heads(&mut k, pos);
        cache.append(layer, &k, &v);

        let keys = cache.keys(layer);
        let vals = cache.values(layer);
        let t = keys.len() / kv_dim; // cached positions incl. current
        let scale = 1.0 / (hd as f32).sqrt();
        let group = self.n_heads / self.n_kv_heads;

        let mut attn_out = vec![0.0f32; q_dim];
        let mut scores = vec![0.0f32; t];
        for h in 0..self.n_heads {
            let kvh = h / group;
            let qh = &q[h * hd..(h + 1) * hd];
            for (ti, score) in scores.iter_mut().enumerate() {
                let kh = &keys[ti * kv_dim + kvh * hd..ti * kv_dim + (kvh + 1) * hd];
                *score = crate::tensor::ops::dot(qh, kh) * scale;
            }
            softmax_inplace(&mut scores);
            let oh = &mut attn_out[h * hd..(h + 1) * hd];
            for (ti, &p) in scores.iter().enumerate() {
                let vh = &vals[ti * kv_dim + kvh * hd..ti * kv_dim + (kvh + 1) * hd];
                for i in 0..hd {
                    oh[i] += p * vh[i];
                }
            }
        }
        self.wo.forward_vec(&attn_out, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::Matrix;

    fn make_attn(d: usize, heads: usize, kv: usize, seed: u64) -> Attention {
        let mut rng = Rng::new(seed);
        let hd = d / heads;
        Attention {
            wq: QuantLinear::dense(Matrix::randn(heads * hd, d, 0.1, &mut rng)),
            wk: QuantLinear::dense(Matrix::randn(kv * hd, d, 0.1, &mut rng)),
            wv: QuantLinear::dense(Matrix::randn(kv * hd, d, 0.1, &mut rng)),
            wo: QuantLinear::dense(Matrix::randn(d, heads * hd, 0.1, &mut rng)),
            n_heads: heads,
            n_kv_heads: kv,
            head_dim: hd,
        }
    }

    #[test]
    fn decode_shapes_and_cache_growth() {
        let attn = make_attn(32, 4, 2, 1);
        let rope = Rope::new(8, 16, 10_000.0);
        let mut cache = KvCache::new(1, 16, 16);
        let mut rng = Rng::new(2);
        for pos in 0..5 {
            let x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
            let mut out = vec![0.0; 32];
            attn.decode(&x, &rope, &mut cache, 0, pos, &mut out);
            cache.commit();
            assert!(out.iter().all(|v| v.is_finite()));
        }
        assert_eq!(cache.len(), 5);
    }

    #[test]
    fn first_token_attends_only_to_itself() {
        // with a single cached position, attention output = wo·v
        let attn = make_attn(16, 2, 2, 3);
        let rope = Rope::new(8, 8, 10_000.0);
        let mut cache = KvCache::new(1, 16, 8);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let mut out = vec![0.0; 16];
        attn.decode(&x, &rope, &mut cache, 0, 0, &mut out);
        // reference: v then wo
        let mut v = vec![0.0; 16];
        attn.wv.forward_vec(&x, &mut v);
        let mut expect = vec![0.0; 16];
        attn.wo.forward_vec(&v, &mut expect);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn gqa_shares_kv_heads() {
        // n_heads=4, n_kv=1: all query heads read the same K/V stripe;
        // output must be finite and deterministic
        let attn = make_attn(32, 4, 1, 5);
        let rope = Rope::new(8, 8, 10_000.0);
        let mut c1 = KvCache::new(1, 8, 8);
        let mut c2 = KvCache::new(1, 8, 8);
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.1).sin()).collect();
        let mut o1 = vec![0.0; 32];
        let mut o2 = vec![0.0; 32];
        attn.decode(&x, &rope, &mut c1, 0, 0, &mut o1);
        attn.decode(&x, &rope, &mut c2, 0, 0, &mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn attends_to_history() {
        // second token's output must depend on the first token's value
        let attn = make_attn(16, 2, 2, 6);
        let rope = Rope::new(8, 8, 10_000.0);
        let x0a: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let x0b: Vec<f32> = (0..16).map(|i| -(i as f32) * 0.1).collect();
        let x1: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).cos()).collect();
        let run = |x0: &[f32]| {
            let mut cache = KvCache::new(1, 16, 8);
            let mut out = vec![0.0; 16];
            attn.decode(x0, &rope, &mut cache, 0, 0, &mut out);
            cache.commit();
            let mut out1 = vec![0.0; 16];
            attn.decode(&x1, &rope, &mut cache, 0, 1, &mut out1);
            out1
        };
        assert!(run(&x0a) != run(&x0b));
    }
}

//! Decoder-only transformer for the Rust serving path.
//!
//! Architecture follows the LLaMA/Qwen recipe the paper evaluates on:
//! RMSNorm (pre-norm), rotary position embeddings, grouped-query
//! attention, SwiGLU MLP, tied or untied LM head. Every linear layer is
//! a [`linear::QuantLinear`] that can run dense f32 **or** packed
//! trit-planes, so an entire checkpoint can be PTQTP-quantized in place
//! and served through the multiply-free kernels.
//!
//! Checkpoints are `.ptw` tensor files written by
//! `python/compile/train.py` (trained in JAX) with a `model.json`
//! config sidecar; [`transformer::Transformer::load`] reads both.

pub mod attention;
pub mod attn_kernels;
pub mod batch;
pub mod config;
pub mod kv;
pub mod linear;
pub mod norm;
pub mod rope;
pub mod transformer;

pub use attention::{AttnScratch, DecodeScratch};
pub use batch::{ForwardBatch, ForwardScratch};
pub use config::ModelConfig;
pub use kv::{CacheFull, KvCache, KvPage, PageStats, PageStore, PagesExhausted};
pub use linear::QuantLinear;
pub use transformer::Transformer;

//! The fused forward batch: one engine step = one stacked matrix pass.
//!
//! [`ForwardBatch`] collects every token the scheduler planned for a
//! step — all prefill chunks plus one decode token per running
//! sequence — as rows tagged with `(position, kv-cache index,
//! needs-logits)`. [`Transformer::forward_batch`] then runs each layer
//! exactly once over the whole stack, so the ternary kernels see
//! enough rows to amortize plane decoding (the paper's deployment
//! speedup condition), instead of being fed one token at a time.
//!
//! [`ForwardScratch`] owns every intermediate buffer the pass needs;
//! the serving engine keeps one alive across steps so the hot loop
//! performs no per-token heap allocation.
//!
//! Dataflow and invariants are documented in `rust/DESIGN.md`
//! §Batched-Forward.
//!
//! [`Transformer::forward_batch`]: super::transformer::Transformer::forward_batch

use super::attention::AttnScratch;
use crate::tensor::Matrix;
use crate::ternary::gemm::GemmScratch;

/// Row-set for one fused forward pass, stored struct-of-arrays so the
/// layer loop can hand the kernels contiguous metadata slices.
///
/// Invariant (checked in debug builds by the attention pass): rows that
/// share a `cache_idx` are contiguous and their positions ascend by 1 —
/// i.e. each sequence contributes one ordered chunk. Rows of different
/// sequences may appear in any order. With the paged KV allocator the
/// engine additionally calls `KvCache::reserve` for every sequence's
/// row count *before* building the batch, so the appends inside the
/// pass can never hit page-pool exhaustion mid-forward.
#[derive(Clone, Debug, Default)]
pub struct ForwardBatch {
    pub tokens: Vec<u32>,
    pub positions: Vec<usize>,
    /// Index into the `caches` slice passed to `forward_batch`.
    pub cache_of: Vec<usize>,
    pub need_logits: Vec<bool>,
    /// Rows per cache index (how many positions to commit per cache).
    per_cache: Vec<usize>,
}

impl ForwardBatch {
    pub fn new() -> ForwardBatch {
        ForwardBatch::default()
    }

    /// Pre-size the row buffers (`StepPlan::batch_rows` upper bound).
    pub fn reserve(&mut self, rows: usize) {
        self.tokens.reserve(rows);
        self.positions.reserve(rows);
        self.cache_of.reserve(rows);
        self.need_logits.reserve(rows);
    }

    /// Drop all rows but keep buffer capacity (per-step reuse).
    pub fn clear(&mut self) {
        self.tokens.clear();
        self.positions.clear();
        self.cache_of.clear();
        self.need_logits.clear();
        self.per_cache.clear();
    }

    /// Add one token row. `pos` must be the next position of the cache
    /// (`committed length + rows already pushed for this cache`).
    pub fn push(&mut self, token: u32, pos: usize, cache_idx: usize, need_logits: bool) {
        self.tokens.push(token);
        self.positions.push(pos);
        self.cache_of.push(cache_idx);
        self.need_logits.push(need_logits);
        if self.per_cache.len() <= cache_idx {
            self.per_cache.resize(cache_idx + 1, 0);
        }
        self.per_cache[cache_idx] += 1;
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Rows contributed by cache `cache_idx` (positions to commit).
    pub fn rows_for_cache(&self, cache_idx: usize) -> usize {
        self.per_cache.get(cache_idx).copied().unwrap_or(0)
    }

    /// Number of caches referenced (max cache index + 1).
    pub fn n_caches(&self) -> usize {
        self.per_cache.len()
    }

    /// Rows flagged as needing logits.
    pub fn n_logit_rows(&self) -> usize {
        self.need_logits.iter().filter(|&&b| b).count()
    }
}

/// Every intermediate buffer of one fused forward pass. Create once,
/// reuse forever: all members grow to the high-water batch shape and
/// are recycled across steps, layers, and sequences.
#[derive(Clone, Debug, Default)]
pub struct ForwardScratch {
    /// Residual stream, batch × d_model.
    pub(crate) x: Matrix,
    /// Pre-norm output, batch × d_model.
    pub(crate) normed: Matrix,
    /// Attention / MLP output added back to the residual.
    pub(crate) delta: Matrix,
    /// SwiGLU intermediates, batch × d_ff.
    pub(crate) gate: Matrix,
    pub(crate) up: Matrix,
    /// Hidden rows that need logits, n_logit_rows × d_model.
    pub(crate) hidden: Matrix,
    /// Attention-pass buffers (q/k/v/scores).
    pub(crate) attn: AttnScratch,
    /// Ternary decode buffers for the MLP / LM-head kernels.
    pub(crate) gemm: GemmScratch,
    /// Reusable batch for the single-row / chunked wrappers
    /// (`decode_step_with`, `prefill`).
    pub(crate) step_batch: ForwardBatch,
    /// Output logits, n_logit_rows × vocab. Valid until the next pass.
    pub logits: Matrix,
}

impl ForwardScratch {
    pub fn new() -> ForwardScratch {
        ForwardScratch::default()
    }

    /// Scratch pre-bound to a worker pool (see [`ForwardScratch::set_pool`]).
    pub fn with_pool(pool: crate::threads::Pool) -> ForwardScratch {
        let mut s = ForwardScratch::default();
        s.set_pool(pool);
        s
    }

    /// Bind the worker pool driving the row-parallel kernels of every
    /// pass using this scratch: MLP/LM-head gemms, the attention
    /// projections, *and* the head-parallel attend stage. The default
    /// is the sequential pool — the exact legacy path; parallel output
    /// is bit-identical either way (DESIGN.md §Threading).
    pub fn set_pool(&mut self, pool: crate::threads::Pool) {
        self.attn.set_pool(pool.clone());
        self.gemm.pool = pool;
    }

    /// The pool bound by [`ForwardScratch::set_pool`] (sequential default).
    pub fn pool(&self) -> &crate::threads::Pool {
        &self.gemm.pool
    }

    /// Toggle the SIMD kernel tiers for every pass using this scratch:
    /// the ternary row-block kernels (MLP/LM-head gemms, attention
    /// projections) and the head-major attention kernels. Default is
    /// the process-wide `--simd`/`PTQTP_SIMD` mode; output is
    /// bit-identical either way (DESIGN.md §SIMD-Kernels and
    /// §Attention-Kernels), so this is a perf/debug knob only.
    pub fn set_simd(&mut self, on: bool) {
        self.gemm.simd = on;
        self.attn.set_simd(on);
    }

    /// Toggle the int8-activation tier for every ternary projection
    /// using this scratch (MLP/LM-head gemms and the attention QKV/O
    /// projections). Unlike [`ForwardScratch::set_simd`] this tier is
    /// **value-changing**, so it defaults to off and is only switched
    /// on by the CLI / serve entry points or explicit A/B callers
    /// (DESIGN.md §Integer-Kernels).
    pub fn set_act_quant(&mut self, on: bool) {
        self.gemm.act_quant = on;
        self.attn.set_act_quant(on);
    }

    /// The int8-activation tier setting carried by this scratch.
    pub fn act_quant(&self) -> bool {
        self.gemm.act_quant
    }
}

/// Resize a scratch matrix, reusing its allocation. Contents zeroed.
pub(crate) fn ensure_shape(m: &mut Matrix, rows: usize, cols: usize) {
    m.rows = rows;
    m.cols = cols;
    m.data.clear();
    m.data.resize(rows * cols, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_tracks_per_cache_counts() {
        let mut b = ForwardBatch::new();
        b.push(1, 0, 0, false);
        b.push(2, 1, 0, true);
        b.push(9, 5, 2, true);
        assert_eq!(b.len(), 3);
        assert_eq!(b.rows_for_cache(0), 2);
        assert_eq!(b.rows_for_cache(1), 0);
        assert_eq!(b.rows_for_cache(2), 1);
        assert_eq!(b.n_caches(), 3);
        assert_eq!(b.n_logit_rows(), 2);
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.n_caches(), 0);
    }

    #[test]
    fn ensure_shape_reuses_allocation() {
        let mut m = Matrix::zeros(4, 4);
        m.data[0] = 7.0;
        let cap = m.data.capacity();
        ensure_shape(&mut m, 2, 3);
        assert_eq!((m.rows, m.cols), (2, 3));
        assert!(m.data.iter().all(|&v| v == 0.0), "stale data cleared");
        assert_eq!(m.data.capacity(), cap, "no realloc when shrinking");
    }
}

//! Rotary position embeddings (RoPE, Su et al.) with precomputed tables.

/// Precomputed cos/sin tables for all positions up to `max_seq`.
#[derive(Clone, Debug)]
pub struct Rope {
    pub head_dim: usize,
    pub max_seq: usize,
    /// cos[pos * half + i], half = head_dim/2
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl Rope {
    pub fn new(head_dim: usize, max_seq: usize, theta: f32) -> Rope {
        assert!(head_dim % 2 == 0, "RoPE needs even head_dim");
        let half = head_dim / 2;
        // frequencies depend only on the pair index, so the powf table
        // is computed once (`half` calls) instead of max_seq × half
        // times — same inputs to the same powf, so the cos/sin tables
        // are bit-identical to the unhoisted form
        let freqs: Vec<f64> = (0..half)
            .map(|i| 1.0 / (theta as f64).powf(2.0 * i as f64 / head_dim as f64))
            .collect();
        let mut cos = Vec::with_capacity(max_seq * half);
        let mut sin = Vec::with_capacity(max_seq * half);
        for pos in 0..max_seq {
            for &freq in &freqs {
                let angle = pos as f64 * freq;
                cos.push(angle.cos() as f32);
                sin.push(angle.sin() as f32);
            }
        }
        Rope {
            head_dim,
            max_seq,
            cos,
            sin,
        }
    }

    /// Rotate one head vector in place for position `pos`.
    /// Pairs (x[2i], x[2i+1]) rotate by the i-th frequency.
    pub fn apply(&self, x: &mut [f32], pos: usize) {
        debug_assert_eq!(x.len(), self.head_dim);
        debug_assert!(pos < self.max_seq, "position {pos} >= max_seq {}", self.max_seq);
        let half = self.head_dim / 2;
        let base = pos * half;
        for i in 0..half {
            let c = self.cos[base + i];
            let s = self.sin[base + i];
            let a = x[2 * i];
            let b = x[2 * i + 1];
            x[2 * i] = a * c - b * s;
            x[2 * i + 1] = a * s + b * c;
        }
    }

    /// Apply to a multi-head vector laid out `[head0 | head1 | ...]`.
    pub fn apply_heads(&self, x: &mut [f32], pos: usize) {
        debug_assert_eq!(x.len() % self.head_dim, 0);
        for head in x.chunks_mut(self.head_dim) {
            self.apply(head, pos);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn position_zero_is_identity() {
        let rope = Rope::new(8, 16, 10_000.0);
        let mut x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let orig = x.clone();
        rope.apply(&mut x, 0);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rotation_preserves_norm() {
        let rope = Rope::new(16, 64, 10_000.0);
        let mut x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).sin()).collect();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        rope.apply(&mut x, 37);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-4);
    }

    #[test]
    fn relative_property_dot_depends_on_distance() {
        // <R(p)q, R(p+k)v> should equal <R(0)q, R(k)v> (relative encoding)
        let rope = Rope::new(8, 64, 10_000.0);
        let q: Vec<f32> = (0..8).map(|i| (i as f32 + 1.0).cos()).collect();
        let v: Vec<f32> = (0..8).map(|i| (i as f32 * 0.3).sin()).collect();
        let dot = |a: &[f32], b: &[f32]| a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>();
        for k in [1usize, 5] {
            let mut q0 = q.clone();
            let mut vk = v.clone();
            rope.apply(&mut q0, 0);
            rope.apply(&mut vk, k);
            let d_ref = dot(&q0, &vk);
            for p in [3usize, 20] {
                let mut qp = q.clone();
                let mut vpk = v.clone();
                rope.apply(&mut qp, p);
                rope.apply(&mut vpk, p + k);
                assert!((dot(&qp, &vpk) - d_ref).abs() < 1e-3, "p={p} k={k}");
            }
        }
    }

    #[test]
    fn hoisted_freq_table_matches_per_position_recompute() {
        // the table build computes each frequency once; entries must be
        // bitwise what the per-(pos, i) recompute produces
        let (head_dim, max_seq, theta) = (8usize, 16usize, 10_000.0f32);
        let rope = Rope::new(head_dim, max_seq, theta);
        let half = head_dim / 2;
        for pos in [0usize, 1, 7, 15] {
            for i in 0..half {
                let freq = 1.0 / (theta as f64).powf(2.0 * i as f64 / head_dim as f64);
                let angle = pos as f64 * freq;
                assert_eq!(rope.cos[pos * half + i], angle.cos() as f32);
                assert_eq!(rope.sin[pos * half + i], angle.sin() as f32);
            }
        }
    }

    #[test]
    fn apply_heads_rotates_each() {
        let rope = Rope::new(4, 8, 10_000.0);
        let mut x = vec![1.0f32, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0]; // 2 heads
        rope.apply_heads(&mut x, 3);
        // both heads transformed identically
        assert_eq!(x[0], x[4]);
        assert_eq!(x[1], x[5]);
        assert!(x[0] != 1.0);
    }
}

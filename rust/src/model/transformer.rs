//! The full decoder-only transformer: embedding → N blocks → norm →
//! LM head, with checkpoint IO and whole-model quantization.

use super::attention::Attention;
use super::config::ModelConfig;
use super::kv::KvCache;
use super::linear::QuantLinear;
use super::norm::RmsNorm;
use super::rope::Rope;
use crate::quant::{QuantCtx, Quantizer};
use crate::serialize::{TensorFile, TensorEntry};
use crate::tensor::Matrix;

/// One transformer block: pre-norm attention + pre-norm SwiGLU MLP.
#[derive(Clone, Debug)]
pub struct Block {
    pub attn_norm: RmsNorm,
    pub attn: Attention,
    pub mlp_norm: RmsNorm,
    pub w_gate: QuantLinear,
    pub w_up: QuantLinear,
    pub w_down: QuantLinear,
}

impl Block {
    /// SwiGLU MLP: down( silu(gate(x)) * up(x) ).
    fn mlp(&self, x: &[f32], out: &mut [f32]) {
        let ff = self.w_gate.out_features();
        let mut g = vec![0.0f32; ff];
        let mut u = vec![0.0f32; ff];
        self.w_gate.forward_vec(x, &mut g);
        self.w_up.forward_vec(x, &mut u);
        for i in 0..ff {
            let s = g[i];
            let silu = s / (1.0 + (-s).exp());
            g[i] = silu * u[i];
        }
        self.w_down.forward_vec(&g, out);
    }

    /// Decode one token through this block (residual stream in `x`).
    pub fn decode(
        &self,
        x: &mut [f32],
        rope: &Rope,
        cache: &mut KvCache,
        layer: usize,
        pos: usize,
    ) {
        let d = x.len();
        let mut normed = vec![0.0f32; d];
        let mut delta = vec![0.0f32; d];
        self.attn_norm.forward(x, &mut normed);
        self.attn.decode(&normed, rope, cache, layer, pos, &mut delta);
        for i in 0..d {
            x[i] += delta[i];
        }
        self.mlp_norm.forward(x, &mut normed);
        self.mlp(&normed, &mut delta);
        for i in 0..d {
            x[i] += delta[i];
        }
    }
}

/// The full model.
#[derive(Clone, Debug)]
pub struct Transformer {
    pub config: ModelConfig,
    pub tok_embed: Matrix, // vocab × d (kept dense: lookup table)
    pub blocks: Vec<Block>,
    pub final_norm: RmsNorm,
    /// None when tied to `tok_embed`.
    pub lm_head: Option<QuantLinear>,
    pub rope: Rope,
}

impl Transformer {
    /// Fresh KV cache sized for this model.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(
            self.config.n_layers,
            self.config.kv_dim(),
            self.config.max_seq,
        )
    }

    /// Decode one token id at position `cache.len()`; returns logits.
    /// The caller owns the cache (enables continuous batching upstream).
    pub fn decode_step(&self, token: u32, cache: &mut KvCache) -> Vec<f32> {
        let pos = cache.len();
        let d = self.config.d_model;
        let mut x = self.tok_embed.row(token as usize).to_vec();
        debug_assert_eq!(x.len(), d);
        for (layer, block) in self.blocks.iter().enumerate() {
            block.decode(&mut x, &self.rope, cache, layer, pos);
        }
        cache.commit();
        self.final_norm.forward_inplace(&mut x);
        self.logits(&x)
    }

    fn logits(&self, h: &[f32]) -> Vec<f32> {
        match &self.lm_head {
            Some(head) => {
                let mut out = vec![0.0f32; self.config.vocab_size];
                head.forward_vec(h, &mut out);
                out
            }
            None => {
                // tied: logits = E·h
                let mut out = vec![0.0f32; self.config.vocab_size];
                crate::tensor::ops::matvec_into(&self.tok_embed, h, &mut out);
                out
            }
        }
    }

    /// Teacher-forced negative log-likelihoods: nll[i] = −log p(t[i+1] | t[..=i]).
    pub fn sequence_nll(&self, tokens: &[u32]) -> Vec<f64> {
        let mut cache = self.new_cache();
        let mut nll = Vec::with_capacity(tokens.len().saturating_sub(1));
        for i in 0..tokens.len().saturating_sub(1) {
            let logits = self.decode_step(tokens[i], &mut cache);
            let logp = crate::tensor::ops::log_softmax(&logits);
            nll.push(-(logp[tokens[i + 1] as usize] as f64));
        }
        nll
    }

    /// Greedy generation from a prompt; returns generated ids (prompt
    /// excluded). Stops at `stop_token` or `max_new`.
    pub fn generate_greedy(&self, prompt: &[u32], max_new: usize, stop_token: Option<u32>) -> Vec<u32> {
        let mut cache = self.new_cache();
        let mut logits = vec![0.0f32; self.config.vocab_size];
        for &t in prompt {
            logits = self.decode_step(t, &mut cache);
            if cache.is_full() {
                return Vec::new();
            }
        }
        let mut out = Vec::new();
        for _ in 0..max_new {
            let next = argmax(&logits) as u32;
            if Some(next) == stop_token {
                break;
            }
            out.push(next);
            if cache.is_full() {
                break;
            }
            logits = self.decode_step(next, &mut cache);
        }
        out
    }

    /// Quantize every linear layer in place with `q`. Embeddings and
    /// norms stay FP (the paper quantizes "all linear layers").
    pub fn quantize_with(&mut self, q: &dyn Quantizer, ctx: &QuantCtx) {
        for b in self.blocks.iter_mut() {
            b.attn.wq.quantize_with(q, ctx);
            b.attn.wk.quantize_with(q, ctx);
            b.attn.wv.quantize_with(q, ctx);
            b.attn.wo.quantize_with(q, ctx);
            b.w_gate.quantize_with(q, ctx);
            b.w_up.quantize_with(q, ctx);
            b.w_down.quantize_with(q, ctx);
        }
        if let Some(head) = self.lm_head.as_mut() {
            head.quantize_with(q, ctx);
        }
    }

    /// All quantizable weight matrices (name, reference) — used by the
    /// quantization pipeline scheduler and the benches.
    pub fn linear_layers(&self) -> Vec<(String, &QuantLinear)> {
        let mut out = Vec::new();
        for (i, b) in self.blocks.iter().enumerate() {
            out.push((format!("L{i}.wq"), &b.attn.wq));
            out.push((format!("L{i}.wk"), &b.attn.wk));
            out.push((format!("L{i}.wv"), &b.attn.wv));
            out.push((format!("L{i}.wo"), &b.attn.wo));
            out.push((format!("L{i}.w_gate"), &b.w_gate));
            out.push((format!("L{i}.w_up"), &b.w_up));
            out.push((format!("L{i}.w_down"), &b.w_down));
        }
        if let Some(h) = &self.lm_head {
            out.push(("lm_head".into(), h));
        }
        out
    }

    /// Total resident weight bytes (embeddings + linears).
    pub fn resident_bytes(&self) -> usize {
        let mut total = self.tok_embed.len() * 4;
        for (_, l) in self.linear_layers() {
            total += l.resident_bytes();
        }
        total
    }

    // ---------------- init & io ----------------

    /// Random init (for tests and the synthetic-weight benches).
    pub fn random(config: ModelConfig, rng: &mut crate::rng::Rng) -> Transformer {
        config.validate().expect("invalid config");
        let d = config.d_model;
        let std = 0.6 / (d as f32).sqrt();
        let blocks = (0..config.n_layers)
            .map(|_| Block {
                attn_norm: RmsNorm::ones(d, config.norm_eps),
                attn: Attention {
                    wq: QuantLinear::dense(Matrix::rand_heavy(d, d, std, rng)),
                    wk: QuantLinear::dense(Matrix::rand_heavy(config.kv_dim(), d, std, rng)),
                    wv: QuantLinear::dense(Matrix::rand_heavy(config.kv_dim(), d, std, rng)),
                    wo: QuantLinear::dense(Matrix::rand_heavy(d, d, std, rng)),
                    n_heads: config.n_heads,
                    n_kv_heads: config.n_kv_heads,
                    head_dim: config.head_dim(),
                },
                mlp_norm: RmsNorm::ones(d, config.norm_eps),
                w_gate: QuantLinear::dense(Matrix::rand_heavy(config.d_ff, d, std, rng)),
                w_up: QuantLinear::dense(Matrix::rand_heavy(config.d_ff, d, std, rng)),
                w_down: QuantLinear::dense(Matrix::rand_heavy(d, config.d_ff, std, rng)),
            })
            .collect();
        Transformer {
            rope: Rope::new(config.head_dim(), config.max_seq, config.rope_theta),
            tok_embed: Matrix::randn(config.vocab_size, d, 0.02, rng),
            blocks,
            final_norm: RmsNorm::ones(d, config.norm_eps),
            lm_head: None,
            config,
        }
    }

    /// Save checkpoint (`.ptw`) + config (`.json`, same stem).
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        let mut tf = TensorFile::new();
        tf.insert_matrix("tok_embed", &self.tok_embed);
        tf.insert(
            "final_norm",
            TensorEntry::from_f32(vec![1, self.config.d_model], &self.final_norm.weight),
        );
        for (i, b) in self.blocks.iter().enumerate() {
            tf.insert(
                &format!("L{i}.attn_norm"),
                TensorEntry::from_f32(vec![1, self.config.d_model], &b.attn_norm.weight),
            );
            tf.insert(
                &format!("L{i}.mlp_norm"),
                TensorEntry::from_f32(vec![1, self.config.d_model], &b.mlp_norm.weight),
            );
            tf.insert_matrix(&format!("L{i}.wq"), &b.attn.wq.dense_weights());
            tf.insert_matrix(&format!("L{i}.wk"), &b.attn.wk.dense_weights());
            tf.insert_matrix(&format!("L{i}.wv"), &b.attn.wv.dense_weights());
            tf.insert_matrix(&format!("L{i}.wo"), &b.attn.wo.dense_weights());
            tf.insert_matrix(&format!("L{i}.w_gate"), &b.w_gate.dense_weights());
            tf.insert_matrix(&format!("L{i}.w_up"), &b.w_up.dense_weights());
            tf.insert_matrix(&format!("L{i}.w_down"), &b.w_down.dense_weights());
        }
        if let Some(h) = &self.lm_head {
            tf.insert_matrix("lm_head", &h.dense_weights());
        }
        tf.save(path)?;
        self.config.save(path.with_extension("json"))?;
        Ok(())
    }

    /// Load checkpoint + config sidecar.
    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<Transformer> {
        let path = path.as_ref();
        let config = ModelConfig::load(path.with_extension("json"))?;
        config.validate()?;
        let tf = TensorFile::load(path)?;
        let d = config.d_model;
        let mut blocks = Vec::with_capacity(config.n_layers);
        for i in 0..config.n_layers {
            blocks.push(Block {
                attn_norm: RmsNorm::new(tf.vec_f32(&format!("L{i}.attn_norm"))?, config.norm_eps),
                mlp_norm: RmsNorm::new(tf.vec_f32(&format!("L{i}.mlp_norm"))?, config.norm_eps),
                attn: Attention {
                    wq: QuantLinear::dense(tf.matrix(&format!("L{i}.wq"))?),
                    wk: QuantLinear::dense(tf.matrix(&format!("L{i}.wk"))?),
                    wv: QuantLinear::dense(tf.matrix(&format!("L{i}.wv"))?),
                    wo: QuantLinear::dense(tf.matrix(&format!("L{i}.wo"))?),
                    n_heads: config.n_heads,
                    n_kv_heads: config.n_kv_heads,
                    head_dim: config.head_dim(),
                },
                w_gate: QuantLinear::dense(tf.matrix(&format!("L{i}.w_gate"))?),
                w_up: QuantLinear::dense(tf.matrix(&format!("L{i}.w_up"))?),
                w_down: QuantLinear::dense(tf.matrix(&format!("L{i}.w_down"))?),
            });
        }
        let tok_embed = tf.matrix("tok_embed")?;
        anyhow::ensure!(
            tok_embed.rows == config.vocab_size && tok_embed.cols == d,
            "tok_embed shape {:?} vs config ({}, {d})",
            (tok_embed.rows, tok_embed.cols),
            config.vocab_size
        );
        let lm_head = if tf.tensors.contains_key("lm_head") {
            Some(QuantLinear::dense(tf.matrix("lm_head")?))
        } else {
            None
        };
        Ok(Transformer {
            rope: Rope::new(config.head_dim(), config.max_seq, config.rope_theta),
            tok_embed,
            blocks,
            final_norm: RmsNorm::new(tf.vec_f32("final_norm")?, config.norm_eps),
            lm_head,
            config,
        })
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ptqtp::Ptqtp;
    use crate::rng::Rng;

    fn tiny_model(seed: u64) -> Transformer {
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(seed);
        Transformer::random(cfg, &mut rng)
    }

    #[test]
    fn decode_step_produces_logits() {
        let m = tiny_model(1);
        let mut cache = m.new_cache();
        let logits = m.decode_step(3, &mut cache);
        assert_eq!(logits.len(), 32);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn decode_deterministic() {
        let m = tiny_model(2);
        let mut c1 = m.new_cache();
        let mut c2 = m.new_cache();
        for t in [1u32, 5, 9] {
            let a = m.decode_step(t, &mut c1);
            let b = m.decode_step(t, &mut c2);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn context_changes_prediction() {
        let m = tiny_model(3);
        let mut c1 = m.new_cache();
        m.decode_step(1, &mut c1);
        let with_ctx = m.decode_step(7, &mut c1);
        let mut c2 = m.new_cache();
        m.decode_step(2, &mut c2);
        let with_other = m.decode_step(7, &mut c2);
        assert!(with_ctx != with_other, "attention must see history");
    }

    #[test]
    fn sequence_nll_length() {
        let m = tiny_model(4);
        let nll = m.sequence_nll(&[1, 2, 3, 4, 5]);
        assert_eq!(nll.len(), 4);
        assert!(nll.iter().all(|&v| v > 0.0 && v.is_finite()));
    }

    #[test]
    fn generate_respects_budgets() {
        let m = tiny_model(5);
        let out = m.generate_greedy(&[1, 2], 6, None);
        assert!(out.len() <= 6);
        for &t in &out {
            assert!((t as usize) < 32);
        }
    }

    #[test]
    fn save_load_roundtrip_exact_logits() {
        let m = tiny_model(6);
        let dir = std::env::temp_dir().join("ptqtp_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ptw");
        m.save(&path).unwrap();
        let m2 = Transformer::load(&path).unwrap();
        let mut c1 = m.new_cache();
        let mut c2 = m2.new_cache();
        for t in [0u32, 3, 7] {
            assert_eq!(m.decode_step(t, &mut c1), m2.decode_step(t, &mut c2));
        }
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(dir.join("m.json")).ok();
    }

    #[test]
    fn quantize_whole_model_stays_close() {
        let m = tiny_model(7);
        let mut mq = m.clone();
        mq.quantize_with(&Ptqtp::default(), &crate::quant::QuantCtx::default());
        assert!(mq.blocks[0].attn.wq.is_ternary());
        // logits correlated with FP model (tiny random model: loose check)
        let mut c1 = m.new_cache();
        let mut c2 = mq.new_cache();
        let a = m.decode_step(1, &mut c1);
        let b = mq.decode_step(1, &mut c2);
        let dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        let cos = dot / (na * nb).max(1e-9);
        assert!(cos > 0.8, "cosine {cos}");
        // memory shrank
        assert!(mq.resident_bytes() < m.resident_bytes());
    }

    #[test]
    fn layer_listing_complete() {
        let m = tiny_model(8);
        let layers = m.linear_layers();
        assert_eq!(layers.len(), m.config.n_layers * 7);
    }
}

//! The full decoder-only transformer: embedding → N blocks → norm →
//! LM head, with checkpoint IO and whole-model quantization.
//!
//! The primary forward path is **batched**: [`Transformer::forward_batch`]
//! runs one fused pass over a [`ForwardBatch`] — any mix of prefill
//! chunks and decode tokens across sequences — hitting each layer's
//! weights exactly once per step. [`Transformer::decode_step`] remains
//! as a thin single-row wrapper so all existing numerics stay pinned.

use super::attention::Attention;
use super::batch::{ensure_shape, ForwardBatch, ForwardScratch};
use super::config::ModelConfig;
use super::kv::KvCache;
use super::linear::{Backend, QuantLinear};
use super::norm::RmsNorm;
use super::rope::Rope;
use crate::quant::{QuantCtx, Quantizer};
use crate::serialize::{CheckpointManifest, Json, TensorEntry, TensorFile};
use crate::tensor::Matrix;

/// Row count per chunk for the prefill/NLL paths: two kernel row-blocks,
/// enough to amortize plane decoding without inflating the logits buffer.
pub const PREFILL_CHUNK: usize = 64;

/// One transformer block: pre-norm attention + pre-norm SwiGLU MLP.
#[derive(Clone, Debug)]
pub struct Block {
    pub attn_norm: RmsNorm,
    pub attn: Attention,
    pub mlp_norm: RmsNorm,
    pub w_gate: QuantLinear,
    pub w_up: QuantLinear,
    pub w_down: QuantLinear,
}

impl Block {
    /// Fused pass of a whole row stack through this block: pre-norm
    /// attention (per-row position/cache) then pre-norm SwiGLU MLP,
    /// residuals updated in `x`. All intermediates live in `scratch` —
    /// no per-token allocation, unlike the old one-token `decode`.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_rows(
        &self,
        x: &mut Matrix,
        rope: &Rope,
        positions: &[usize],
        cache_of: &[usize],
        caches: &mut [&mut KvCache],
        layer: usize,
        scratch: &mut ForwardScratch,
    ) {
        let n = x.rows;
        let d = x.cols;
        ensure_shape(&mut scratch.normed, n, d);
        ensure_shape(&mut scratch.delta, n, d);
        for i in 0..n {
            self.attn_norm.forward(x.row(i), scratch.normed.row_mut(i));
        }
        self.attn.decode_rows(
            &scratch.normed,
            positions,
            cache_of,
            rope,
            caches,
            layer,
            &mut scratch.attn,
            &mut scratch.delta,
        );
        for i in 0..n {
            let xr = x.row_mut(i);
            let dr = scratch.delta.row(i);
            for j in 0..d {
                xr[j] += dr[j];
            }
        }
        for i in 0..n {
            self.mlp_norm.forward(x.row(i), scratch.normed.row_mut(i));
        }
        let ff = self.w_gate.out_features();
        ensure_shape(&mut scratch.gate, n, ff);
        ensure_shape(&mut scratch.up, n, ff);
        self.w_gate
            .forward_rows_into(&scratch.normed, &mut scratch.gate, &mut scratch.gemm);
        self.w_up
            .forward_rows_into(&scratch.normed, &mut scratch.up, &mut scratch.gemm);
        for i in 0..n {
            let g = scratch.gate.row_mut(i);
            let u = scratch.up.row(i);
            for j in 0..ff {
                let s = g[j];
                let silu = s / (1.0 + (-s).exp());
                g[j] = silu * u[j];
            }
        }
        self.w_down
            .forward_rows_into(&scratch.gate, &mut scratch.delta, &mut scratch.gemm);
        for i in 0..n {
            let xr = x.row_mut(i);
            let dr = scratch.delta.row(i);
            for j in 0..d {
                xr[j] += dr[j];
            }
        }
    }
}

/// The full model.
#[derive(Clone, Debug)]
pub struct Transformer {
    pub config: ModelConfig,
    pub tok_embed: Matrix, // vocab × d (kept dense: lookup table)
    pub blocks: Vec<Block>,
    pub final_norm: RmsNorm,
    /// None when tied to `tok_embed`.
    pub lm_head: Option<QuantLinear>,
    pub rope: Rope,
    /// Pool bound into every self-managed [`ForwardScratch`]
    /// ([`Transformer::new_scratch`]) — covers eval / NLL / greedy
    /// paths that don't hold an engine scratch. Sequential by default
    /// (never serialized; [`Transformer::set_threads`] to change).
    /// Output is bit-identical for any lane count.
    pub exec_pool: crate::threads::Pool,
    /// Int8-activation tier for every self-managed pass (and inherited
    /// by engines at construction). **Value-changing** — unlike
    /// `exec_pool`/SIMD this perturbs outputs, so it defaults to off
    /// everywhere and is only flipped by the CLI front-ends
    /// (`--act-quant`/`PTQTP_ACT_QUANT`) or explicit A/B callers
    /// (DESIGN.md §Integer-Kernels).
    pub exec_act_quant: bool,
}

impl Transformer {
    /// Fresh KV cache sized for this model.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(
            self.config.n_layers,
            self.config.n_kv_heads,
            self.config.head_dim(),
            self.config.max_seq,
        )
    }

    /// Fresh scratch for the batched forward path. One per engine (or
    /// per thread); every buffer inside is reused across steps. Bound
    /// to [`Transformer::exec_pool`].
    pub fn new_scratch(&self) -> ForwardScratch {
        let mut s = ForwardScratch::with_pool(self.exec_pool.clone());
        s.set_act_quant(self.exec_act_quant);
        s
    }

    /// Run this model's self-managed passes (eval, NLL, greedy
    /// generation) on `threads` worker lanes. `1` restores the exact
    /// sequential path; results are bit-identical either way.
    pub fn set_threads(&mut self, threads: usize) {
        self.exec_pool = crate::threads::Pool::new(threads);
    }

    /// Enable/disable the int8-activation tier for every self-managed
    /// pass and every scratch created by [`Transformer::new_scratch`]
    /// from here on (engines inherit it at construction). Off by
    /// default — the tier is value-changing (DESIGN.md
    /// §Integer-Kernels), so only the CLI front-ends or explicit A/B
    /// callers flip it.
    pub fn set_act_quant(&mut self, on: bool) {
        self.exec_act_quant = on;
    }

    /// One fused pass over `batch`: embed all rows, run every layer
    /// once over the whole stack (attention per row against its own
    /// cache), commit each touched cache by its row count, then compute
    /// logits for the rows that asked for them into `scratch.logits`
    /// (row order = batch order of `need_logits` rows). Returns the
    /// number of logits rows.
    ///
    /// `caches[batch.cache_of[i]]` is row `i`'s sequence cache; rows of
    /// one cache must be contiguous with consecutive positions starting
    /// at the cache's committed length.
    ///
    /// Per row this is bit-identical to [`Transformer::decode_step`] —
    /// the batched kernels replay the per-token FP operation order —
    /// which is what lets the serving engine fuse prefill and decode
    /// into one matrix step without changing any sequence's tokens.
    pub fn forward_batch(
        &self,
        batch: &ForwardBatch,
        caches: &mut [&mut KvCache],
        scratch: &mut ForwardScratch,
    ) -> usize {
        let n = batch.len();
        let d = self.config.d_model;
        debug_assert!(batch.n_caches() <= caches.len());
        if n == 0 {
            ensure_shape(&mut scratch.logits, 0, self.config.vocab_size);
            return 0;
        }
        let mut x = std::mem::take(&mut scratch.x);
        ensure_shape(&mut x, n, d);
        for i in 0..n {
            x.row_mut(i)
                .copy_from_slice(self.tok_embed.row(batch.tokens[i] as usize));
        }
        for (layer, block) in self.blocks.iter().enumerate() {
            block.forward_rows(
                &mut x,
                &self.rope,
                &batch.positions,
                &batch.cache_of,
                caches,
                layer,
                scratch,
            );
        }
        for (ci, cache) in caches.iter_mut().enumerate() {
            let rows = batch.rows_for_cache(ci);
            if rows > 0 {
                cache.commit_n(rows);
            }
        }
        let n_logits = batch.n_logit_rows();
        ensure_shape(&mut scratch.hidden, n_logits, d);
        let mut li = 0;
        for i in 0..n {
            if batch.need_logits[i] {
                self.final_norm.forward(x.row(i), scratch.hidden.row_mut(li));
                li += 1;
            }
        }
        ensure_shape(&mut scratch.logits, n_logits, self.config.vocab_size);
        match &self.lm_head {
            Some(head) => {
                head.forward_rows_into(&scratch.hidden, &mut scratch.logits, &mut scratch.gemm)
            }
            None => {
                // tied: logits = E·h, row-exact with the decode path;
                // lanes take whole logits rows (deep batches) or vocab
                // spans (single decode row) — bit-identical either way
                crate::tensor::ops::matvec_rows_pooled(
                    &self.tok_embed,
                    &scratch.hidden,
                    &mut scratch.logits,
                    &scratch.gemm.pool,
                );
            }
        }
        scratch.x = x;
        n_logits
    }

    /// Decode one token id at position `cache.len()`; returns logits.
    /// The caller owns the cache (enables continuous batching upstream).
    ///
    /// Thin single-row wrapper over [`Transformer::forward_batch`];
    /// allocates its own scratch per call — hot loops should hold a
    /// [`ForwardScratch`] and use [`Transformer::decode_step_with`].
    pub fn decode_step(&self, token: u32, cache: &mut KvCache) -> Vec<f32> {
        let mut scratch = self.new_scratch();
        self.decode_step_with(token, cache, &mut scratch).to_vec()
    }

    /// Allocation-free decode step: one row through the batched path,
    /// returning the logits slice inside `scratch`.
    pub fn decode_step_with<'s>(
        &self,
        token: u32,
        cache: &mut KvCache,
        scratch: &'s mut ForwardScratch,
    ) -> &'s [f32] {
        let mut b = std::mem::take(&mut scratch.step_batch);
        b.clear();
        b.push(token, cache.len(), 0, true);
        self.forward_batch(&b, &mut [&mut *cache], scratch);
        scratch.step_batch = b;
        scratch.logits.row(0)
    }

    /// Chunked prefill through the batched path: consumes `tokens` in
    /// chunks of `chunk` rows and returns the logits after the last
    /// token (all zeros when `tokens` is empty).
    pub fn prefill(
        &self,
        tokens: &[u32],
        cache: &mut KvCache,
        scratch: &mut ForwardScratch,
        chunk: usize,
    ) -> Vec<f32> {
        let chunk = chunk.max(1);
        let mut logits = vec![0.0f32; self.config.vocab_size];
        let mut i = 0;
        while i < tokens.len() {
            let take = (tokens.len() - i).min(chunk);
            let mut b = std::mem::take(&mut scratch.step_batch);
            b.clear();
            let base = cache.len();
            for j in 0..take {
                b.push(tokens[i + j], base + j, 0, i + j + 1 == tokens.len());
            }
            self.forward_batch(&b, &mut [&mut *cache], scratch);
            scratch.step_batch = b;
            i += take;
        }
        if !tokens.is_empty() {
            logits.copy_from_slice(scratch.logits.row(0));
        }
        logits
    }

    /// Teacher-forced negative log-likelihoods: nll[i] = −log p(t[i+1] | t[..=i]).
    /// Runs the batched path with chunked all-position logits.
    pub fn sequence_nll(&self, tokens: &[u32]) -> Vec<f64> {
        let mut cache = self.new_cache();
        let mut scratch = self.new_scratch();
        let n = tokens.len().saturating_sub(1);
        let mut nll = Vec::with_capacity(n);
        let mut i = 0;
        while i < n {
            let take = (n - i).min(PREFILL_CHUNK);
            let mut b = std::mem::take(&mut scratch.step_batch);
            b.clear();
            for j in 0..take {
                b.push(tokens[i + j], i + j, 0, true);
            }
            self.forward_batch(&b, &mut [&mut cache], &mut scratch);
            scratch.step_batch = b;
            for j in 0..take {
                let logp = crate::tensor::ops::log_softmax(scratch.logits.row(j));
                nll.push(-(logp[tokens[i + j + 1] as usize] as f64));
            }
            i += take;
        }
        nll
    }

    /// Greedy generation from a prompt; returns generated ids (prompt
    /// excluded). Stops at `stop_token` or `max_new`. Prefill runs
    /// chunked through the batched path; decode reuses one scratch.
    pub fn generate_greedy(&self, prompt: &[u32], max_new: usize, stop_token: Option<u32>) -> Vec<u32> {
        let mut cache = self.new_cache();
        let mut scratch = self.new_scratch();
        if prompt.len() >= self.config.max_seq {
            // prompt alone fills the cache: nothing can be generated
            return Vec::new();
        }
        let mut logits = self.prefill(prompt, &mut cache, &mut scratch, PREFILL_CHUNK);
        let mut out = Vec::new();
        for _ in 0..max_new {
            let next = argmax(&logits) as u32;
            if Some(next) == stop_token {
                break;
            }
            out.push(next);
            if cache.is_full() {
                break;
            }
            logits.copy_from_slice(self.decode_step_with(next, &mut cache, &mut scratch));
        }
        out
    }

    /// Quantize every linear layer in place with `q`. Embeddings and
    /// norms stay FP (the paper quantizes "all linear layers").
    ///
    /// When `ctx.pool` has worker lanes, the matrices are partitioned
    /// across them (each lane quantizes whole matrices with an inner
    /// sequential context — [`crate::threads`] pools must not nest).
    /// Each matrix's result is independent of every other, so the
    /// quantized model is bit-identical for any thread count. With a
    /// sequential (or single-matrix) context the per-matrix call runs
    /// inline, where PTQTP itself row-parallelizes on `ctx.pool`.
    pub fn quantize_with(&mut self, q: &dyn Quantizer, ctx: &QuantCtx) {
        let pool = ctx.pool.clone();
        let mut layers: Vec<&mut QuantLinear> = Vec::new();
        for b in self.blocks.iter_mut() {
            layers.push(&mut b.attn.wq);
            layers.push(&mut b.attn.wk);
            layers.push(&mut b.attn.wv);
            layers.push(&mut b.attn.wo);
            layers.push(&mut b.w_gate);
            layers.push(&mut b.w_up);
            layers.push(&mut b.w_down);
        }
        if let Some(head) = self.lm_head.as_mut() {
            layers.push(head);
        }
        let lanes = pool.threads();
        if lanes <= 1 || layers.len() < 2 {
            for l in layers {
                l.quantize_with(q, ctx);
            }
            return;
        }
        let mut ctx_inner = ctx.clone();
        ctx_inner.pool = crate::threads::Pool::sequential();
        let n = layers.len();
        crate::threads::run_spans(&pool, n, 1, &mut layers, |_, _, span| {
            for l in span.iter_mut() {
                l.quantize_with(q, &ctx_inner);
            }
        });
    }

    /// All quantizable weight matrices (name, reference) — used by the
    /// quantization pipeline scheduler and the benches.
    pub fn linear_layers(&self) -> Vec<(String, &QuantLinear)> {
        let mut out = Vec::new();
        for (i, b) in self.blocks.iter().enumerate() {
            out.push((format!("L{i}.wq"), &b.attn.wq));
            out.push((format!("L{i}.wk"), &b.attn.wk));
            out.push((format!("L{i}.wv"), &b.attn.wv));
            out.push((format!("L{i}.wo"), &b.attn.wo));
            out.push((format!("L{i}.w_gate"), &b.w_gate));
            out.push((format!("L{i}.w_up"), &b.w_up));
            out.push((format!("L{i}.w_down"), &b.w_down));
        }
        if let Some(h) = &self.lm_head {
            out.push(("lm_head".into(), h));
        }
        out
    }

    /// Total resident weight bytes (embeddings + linears).
    pub fn resident_bytes(&self) -> usize {
        let mut total = self.tok_embed.len() * 4;
        for (_, l) in self.linear_layers() {
            total += l.resident_bytes();
        }
        total
    }

    /// How many linear layers currently hold a packed trit-plane
    /// backend. `> 0` after loading a `PTW2` quantized checkpoint —
    /// the serve/eval front-ends use this to skip the quantization
    /// pass entirely (quantize once, serve many).
    pub fn ternary_layers(&self) -> usize {
        self.linear_layers()
            .iter()
            .filter(|(_, l)| l.is_ternary())
            .count()
    }

    /// Linear layers currently carrying a SIMD-interleaved layout — the
    /// layers the SIMD tier can actually serve. 0 when the mode is
    /// `off`, on ragged-only quantizations (`G % 4 != 0`), or on a
    /// dense model; the serve front-end prints this next to the tier
    /// name so "simd avx2" can't mislead when every dispatch ran
    /// scalar.
    pub fn simd_layers(&self) -> usize {
        self.linear_layers()
            .iter()
            .filter(|(_, l)| {
                matches!(&l.backend, Backend::Ternary(t) if t.interleave.is_some())
            })
            .count()
    }

    /// Linear layers the int8-activation tier can actually serve:
    /// packed ternary backends with a LUT-aligned layout (`G % 4 == 0`,
    /// `cols % 4 == 0`) and enough rows to amortize table builds.
    /// Ragged or short layers silently stay on the f32 tiers even when
    /// the knob is on; the serve front-end prints this next to the tier
    /// name so "act-quant int8" can't mislead when every dispatch ran
    /// f32.
    pub fn act_quant_layers(&self) -> usize {
        self.linear_layers()
            .iter()
            .filter(|(_, l)| {
                matches!(&l.backend, Backend::Ternary(t)
                    if crate::ternary::lut::is_aligned(t)
                        && t.rows >= crate::ternary::lut::LUT_MIN_ROWS)
            })
            .count()
    }

    /// Container revision [`Transformer::save`] will emit for the
    /// current backends.
    pub fn checkpoint_format(&self) -> &'static str {
        if self.ternary_layers() > 0 {
            "PTW2"
        } else {
            "PTW1"
        }
    }

    /// Aggregate quantization summary for the checkpoint manifest:
    /// layer counts, footprint, per-plane sparsity, and scale
    /// magnitude (all derivable from the quantized weights, so the
    /// record stays truthful for any quantizer).
    pub fn quant_summary(&self) -> Json {
        let layers = self.linear_layers();
        let mut ternary = 0usize;
        let mut weights = 0usize; // total ternary weights
        let mut alphas = 0usize; // total scale entries (both planes)
        let mut s1 = 0.0f64;
        let mut s2 = 0.0f64;
        let mut abs_alpha = 0.0f64;
        let mut bits = 0.0f64;
        for (_, l) in &layers {
            if let Backend::Ternary(t) = &l.backend {
                ternary += 1;
                // weight every aggregate by layer size, so the record
                // means total-bits / total-weights (not a per-layer
                // mean that a single tiny layer could skew)
                let wn = (t.rows * t.cols) as f64;
                let an = 2 * t.alpha1.len();
                weights += t.rows * t.cols;
                alphas += an;
                let u = t.unpack();
                s1 += u.t1.sparsity() * wn;
                s2 += u.t2.sparsity() * wn;
                abs_alpha += u.mean_abs_alpha() * an as f64;
                bits += u.bits_per_weight() * wn;
            }
        }
        let wn = weights.max(1) as f64;
        Json::obj()
            .set("layers_total", layers.len())
            .set("layers_ternary", ternary)
            .set("ternary_weights", weights)
            .set("resident_bytes", self.resident_bytes())
            .set("bits_per_weight", bits / wn)
            .set("t1_sparsity", s1 / wn)
            .set("t2_sparsity", s2 / wn)
            .set("mean_abs_alpha", abs_alpha / alphas.max(1) as f64)
    }

    // ---------------- init & io ----------------

    /// Random init (for tests and the synthetic-weight benches).
    pub fn random(config: ModelConfig, rng: &mut crate::rng::Rng) -> Transformer {
        config.validate().expect("invalid config");
        let d = config.d_model;
        let std = 0.6 / (d as f32).sqrt();
        let blocks = (0..config.n_layers)
            .map(|_| Block {
                attn_norm: RmsNorm::ones(d, config.norm_eps),
                attn: Attention {
                    wq: QuantLinear::dense(Matrix::rand_heavy(d, d, std, rng)),
                    wk: QuantLinear::dense(Matrix::rand_heavy(config.kv_dim(), d, std, rng)),
                    wv: QuantLinear::dense(Matrix::rand_heavy(config.kv_dim(), d, std, rng)),
                    wo: QuantLinear::dense(Matrix::rand_heavy(d, d, std, rng)),
                    n_heads: config.n_heads,
                    n_kv_heads: config.n_kv_heads,
                    head_dim: config.head_dim(),
                },
                mlp_norm: RmsNorm::ones(d, config.norm_eps),
                w_gate: QuantLinear::dense(Matrix::rand_heavy(config.d_ff, d, std, rng)),
                w_up: QuantLinear::dense(Matrix::rand_heavy(config.d_ff, d, std, rng)),
                w_down: QuantLinear::dense(Matrix::rand_heavy(d, config.d_ff, std, rng)),
            })
            .collect();
        Transformer {
            rope: Rope::new(config.head_dim(), config.max_seq, config.rope_theta),
            tok_embed: Matrix::randn(config.vocab_size, d, 0.02, rng),
            blocks,
            final_norm: RmsNorm::ones(d, config.norm_eps),
            lm_head: None,
            config,
            exec_pool: crate::threads::Pool::sequential(),
            exec_act_quant: false,
        }
    }

    /// Save checkpoint (`.ptw`) + config (`.json`) + manifest
    /// (`.manifest.json`), all on the same stem.
    ///
    /// Dense layers serialize as plain f32 tensors (`PTW1`, readable by
    /// the Python tooling). Ternary backends serialize as packed
    /// trit-plane records (`PTW2`) — **no densification**: the exact
    /// planes and f32 scales the kernels stream go to disk, so a loaded
    /// model is bit-identical to the saved one.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        let method = if self.ternary_layers() > 0 { "packed" } else { "fp32" };
        self.save_with_manifest(path, method, None, None)
    }

    /// [`Transformer::save`] with explicit manifest metadata: the
    /// quantization method name, its hyper-parameters, and a
    /// quantization report (`cmd_quantize` passes all three so the
    /// artifact documents its own provenance).
    pub fn save_with_manifest(
        &self,
        path: impl AsRef<std::path::Path>,
        method: &str,
        quant_opts: Option<Json>,
        report: Option<Json>,
    ) -> anyhow::Result<()> {
        let path = path.as_ref();
        let mut tf = TensorFile::new();
        let put = |tf: &mut TensorFile, name: &str, l: &QuantLinear| match &l.backend {
            Backend::Dense(w) => tf.insert_matrix(name, w),
            Backend::Ternary(t) => tf.insert_packed(name, t),
        };
        tf.insert_matrix("tok_embed", &self.tok_embed);
        tf.insert(
            "final_norm",
            TensorEntry::from_f32(vec![1, self.config.d_model], &self.final_norm.weight),
        );
        for (i, b) in self.blocks.iter().enumerate() {
            tf.insert(
                &format!("L{i}.attn_norm"),
                TensorEntry::from_f32(vec![1, self.config.d_model], &b.attn_norm.weight),
            );
            tf.insert(
                &format!("L{i}.mlp_norm"),
                TensorEntry::from_f32(vec![1, self.config.d_model], &b.mlp_norm.weight),
            );
            put(&mut tf, &format!("L{i}.wq"), &b.attn.wq);
            put(&mut tf, &format!("L{i}.wk"), &b.attn.wk);
            put(&mut tf, &format!("L{i}.wv"), &b.attn.wv);
            put(&mut tf, &format!("L{i}.wo"), &b.attn.wo);
            put(&mut tf, &format!("L{i}.w_gate"), &b.w_gate);
            put(&mut tf, &format!("L{i}.w_up"), &b.w_up);
            put(&mut tf, &format!("L{i}.w_down"), &b.w_down);
        }
        if let Some(h) = &self.lm_head {
            put(&mut tf, "lm_head", h);
        }
        // stream to disk through a hashing writer: the checksum covers
        // exactly the bytes written, with no extra in-memory copy
        let file = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("create {path:?}: {e}"))?;
        let mut w = crate::serialize::HashingWriter::new(std::io::BufWriter::new(file));
        tf.write_to(&mut w)?;
        let (payload_bytes, digest) = w.finish()?;
        let mut manifest = CheckpointManifest::from_digest(
            tf.format(),
            method,
            payload_bytes,
            digest,
            tf.tensors.len(),
            tf.packed.len(),
        );
        manifest.quant_opts = quant_opts;
        manifest.report = report;
        manifest.save_for(path)?;
        self.config.save(path.with_extension("json"))?;
        Ok(())
    }

    /// Load checkpoint + config sidecar. When a manifest sidecar is
    /// present, the whole-file checksum and size are verified as the
    /// payload streams in (a corrupt artifact fails with a clear
    /// checksum/size — or parse — error, never a silently wrong
    /// model); `PTW1` files without a manifest — e.g. from the Python
    /// build path — load as before. Packed trit-plane records come
    /// back as ternary backends with **no requantization**.
    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<Transformer> {
        let path = path.as_ref();
        let config = ModelConfig::load(path.with_extension("json"))?;
        config.validate()?;
        let manifest = CheckpointManifest::load_for(path)?;
        let file =
            std::fs::File::open(path).map_err(|e| anyhow::anyhow!("open {path:?}: {e}"))?;
        let mut r = crate::serialize::HashingReader::new(std::io::BufReader::new(file));
        let tf = TensorFile::read_from(&mut r)?;
        if let Some(manifest) = manifest {
            // finish() drains to EOF so the digest (and size check)
            // covers the whole file, trailing bytes included
            let (payload_bytes, digest) = r.finish()?;
            manifest
                .verify_digest(payload_bytes, digest)
                .map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        }
        let d = config.d_model;
        // packed records are MOVED out of the container (not cloned):
        // cold start holds one copy of the planes, not two
        fn lin(
            tf: &mut TensorFile,
            name: String,
            out_f: usize,
            in_f: usize,
        ) -> anyhow::Result<QuantLinear> {
            if let Some(p) = tf.packed.remove(&name) {
                anyhow::ensure!(
                    p.rows == out_f && p.cols == in_f,
                    "packed '{name}' shape ({}, {}) vs expected ({out_f}, {in_f})",
                    p.rows,
                    p.cols
                );
                Ok(QuantLinear::from_packed(p))
            } else {
                let m = tf.matrix(&name)?;
                anyhow::ensure!(
                    m.rows == out_f && m.cols == in_f,
                    "tensor '{name}' shape ({}, {}) vs expected ({out_f}, {in_f})",
                    m.rows,
                    m.cols
                );
                Ok(QuantLinear::dense(m))
            }
        }
        let mut tf = tf;
        let kv = config.kv_dim();
        let ff = config.d_ff;
        let mut blocks = Vec::with_capacity(config.n_layers);
        for i in 0..config.n_layers {
            blocks.push(Block {
                attn_norm: RmsNorm::new(tf.vec_f32(&format!("L{i}.attn_norm"))?, config.norm_eps),
                mlp_norm: RmsNorm::new(tf.vec_f32(&format!("L{i}.mlp_norm"))?, config.norm_eps),
                attn: Attention {
                    wq: lin(&mut tf, format!("L{i}.wq"), d, d)?,
                    wk: lin(&mut tf, format!("L{i}.wk"), kv, d)?,
                    wv: lin(&mut tf, format!("L{i}.wv"), kv, d)?,
                    wo: lin(&mut tf, format!("L{i}.wo"), d, d)?,
                    n_heads: config.n_heads,
                    n_kv_heads: config.n_kv_heads,
                    head_dim: config.head_dim(),
                },
                w_gate: lin(&mut tf, format!("L{i}.w_gate"), ff, d)?,
                w_up: lin(&mut tf, format!("L{i}.w_up"), ff, d)?,
                w_down: lin(&mut tf, format!("L{i}.w_down"), d, ff)?,
            });
        }
        let tok_embed = tf.matrix("tok_embed")?;
        anyhow::ensure!(
            tok_embed.rows == config.vocab_size && tok_embed.cols == d,
            "tok_embed shape {:?} vs config ({}, {d})",
            (tok_embed.rows, tok_embed.cols),
            config.vocab_size
        );
        let lm_head = if tf.has("lm_head") {
            Some(lin(&mut tf, "lm_head".to_string(), config.vocab_size, d)?)
        } else {
            None
        };
        Ok(Transformer {
            rope: Rope::new(config.head_dim(), config.max_seq, config.rope_theta),
            tok_embed,
            blocks,
            final_norm: RmsNorm::new(tf.vec_f32("final_norm")?, config.norm_eps),
            lm_head,
            config,
            exec_pool: crate::threads::Pool::sequential(),
            exec_act_quant: false,
        })
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ptqtp::Ptqtp;
    use crate::rng::Rng;

    fn tiny_model(seed: u64) -> Transformer {
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(seed);
        Transformer::random(cfg, &mut rng)
    }

    #[test]
    fn decode_step_produces_logits() {
        let m = tiny_model(1);
        let mut cache = m.new_cache();
        let logits = m.decode_step(3, &mut cache);
        assert_eq!(logits.len(), 32);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn decode_deterministic() {
        let m = tiny_model(2);
        let mut c1 = m.new_cache();
        let mut c2 = m.new_cache();
        for t in [1u32, 5, 9] {
            let a = m.decode_step(t, &mut c1);
            let b = m.decode_step(t, &mut c2);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn context_changes_prediction() {
        let m = tiny_model(3);
        let mut c1 = m.new_cache();
        m.decode_step(1, &mut c1);
        let with_ctx = m.decode_step(7, &mut c1);
        let mut c2 = m.new_cache();
        m.decode_step(2, &mut c2);
        let with_other = m.decode_step(7, &mut c2);
        assert!(with_ctx != with_other, "attention must see history");
    }

    #[test]
    fn sequence_nll_length() {
        let m = tiny_model(4);
        let nll = m.sequence_nll(&[1, 2, 3, 4, 5]);
        assert_eq!(nll.len(), 4);
        assert!(nll.iter().all(|&v| v > 0.0 && v.is_finite()));
    }

    #[test]
    fn generate_respects_budgets() {
        let m = tiny_model(5);
        let out = m.generate_greedy(&[1, 2], 6, None);
        assert!(out.len() <= 6);
        for &t in &out {
            assert!((t as usize) < 32);
        }
    }

    #[test]
    fn save_load_roundtrip_exact_logits() {
        let m = tiny_model(6);
        let dir = std::env::temp_dir().join("ptqtp_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ptw");
        m.save(&path).unwrap();
        let m2 = Transformer::load(&path).unwrap();
        let mut c1 = m.new_cache();
        let mut c2 = m2.new_cache();
        for t in [0u32, 3, 7] {
            assert_eq!(m.decode_step(t, &mut c1), m2.decode_step(t, &mut c2));
        }
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(dir.join("m.json")).ok();
        std::fs::remove_file(dir.join("m.manifest.json")).ok();
    }

    #[test]
    fn packed_save_load_roundtrip_bit_exact_logits() {
        // quantized models persist their planes directly (PTW2): the
        // loaded model must produce the SAME bits, not approximately
        for group in [128usize, 10] {
            let mut m = tiny_model(20 + group as u64);
            m.quantize_with(
                &Ptqtp::new(crate::quant::ptqtp::PtqtpOpts {
                    group,
                    ..Default::default()
                }),
                &crate::quant::QuantCtx::default(),
            );
            assert_eq!(m.checkpoint_format(), "PTW2");
            let dir = std::env::temp_dir().join(format!("ptqtp_packed_rt_{group}"));
            std::fs::create_dir_all(&dir).unwrap();
            let path = dir.join("q.ptw");
            m.save(&path).unwrap();
            // the file really is PTW2 and carries a manifest sidecar
            let magic = &std::fs::read(&path).unwrap()[..4];
            assert_eq!(magic, b"PTW2", "G={group}");
            let manifest = CheckpointManifest::load_for(&path).unwrap().unwrap();
            assert_eq!(manifest.format, "PTW2");
            assert_eq!(manifest.packed_tensors, m.ternary_layers());

            let m2 = Transformer::load(&path).unwrap();
            assert_eq!(m2.ternary_layers(), m.ternary_layers(), "G={group}");
            assert_eq!(m2.resident_bytes(), m.resident_bytes(), "G={group}");
            let mut c1 = m.new_cache();
            let mut c2 = m2.new_cache();
            for t in [0u32, 3, 7, 1] {
                assert_eq!(
                    m.decode_step(t, &mut c1),
                    m2.decode_step(t, &mut c2),
                    "G={group}: loaded logits drifted"
                );
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn corrupted_checkpoint_fails_checksum() {
        let mut m = tiny_model(22);
        m.quantize_with(&Ptqtp::default(), &crate::quant::QuantCtx::default());
        let dir = std::env::temp_dir().join("ptqtp_corrupt_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("q.ptw");
        m.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = Transformer::load(&path).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fp_checkpoints_stay_ptw1_with_manifest() {
        // dense models keep the Python-readable revision; the manifest
        // records method fp32
        let m = tiny_model(23);
        assert_eq!(m.checkpoint_format(), "PTW1");
        let dir = std::env::temp_dir().join("ptqtp_fp_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fp.ptw");
        m.save(&path).unwrap();
        assert_eq!(&std::fs::read(&path).unwrap()[..4], b"PTW1");
        let manifest = CheckpointManifest::load_for(&path).unwrap().unwrap();
        assert_eq!((manifest.format.as_str(), manifest.method.as_str()), ("PTW1", "fp32"));
        assert_eq!(manifest.packed_tensors, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quant_summary_reflects_backends() {
        let mut m = tiny_model(24);
        let before = m.quant_summary();
        assert_eq!(before.req_usize("layers_ternary").unwrap(), 0);
        m.quantize_with(&Ptqtp::default(), &crate::quant::QuantCtx::default());
        let after = m.quant_summary();
        assert_eq!(
            after.req_usize("layers_ternary").unwrap(),
            m.linear_layers().len()
        );
        assert!(after.req_f64("bits_per_weight").unwrap() > 0.0);
        assert!(
            after.req_usize("resident_bytes").unwrap()
                < before.req_usize("resident_bytes").unwrap()
        );
    }

    #[test]
    fn quantize_whole_model_stays_close() {
        let m = tiny_model(7);
        let mut mq = m.clone();
        mq.quantize_with(&Ptqtp::default(), &crate::quant::QuantCtx::default());
        assert!(mq.blocks[0].attn.wq.is_ternary());
        // logits correlated with FP model (tiny random model: loose check)
        let mut c1 = m.new_cache();
        let mut c2 = mq.new_cache();
        let a = m.decode_step(1, &mut c1);
        let b = mq.decode_step(1, &mut c2);
        let dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        let cos = dot / (na * nb).max(1e-9);
        assert!(cos > 0.8, "cosine {cos}");
        // memory shrank
        assert!(mq.resident_bytes() < m.resident_bytes());
    }

    #[test]
    fn simd_layers_counts_interleaved_backends() {
        let mut m = tiny_model(25);
        assert_eq!(m.simd_layers(), 0, "dense model has no interleaves");
        m.quantize_with(&Ptqtp::default(), &crate::quant::QuantCtx::default());
        let total = m.linear_layers().len();
        // force layouts on/off explicitly so the count is deterministic
        // regardless of the process-wide SIMD mode
        let for_each = |m: &mut Transformer, lanes: Option<usize>| {
            for b in m.blocks.iter_mut() {
                for l in [
                    &mut b.attn.wq,
                    &mut b.attn.wk,
                    &mut b.attn.wv,
                    &mut b.attn.wo,
                    &mut b.w_gate,
                    &mut b.w_up,
                    &mut b.w_down,
                ] {
                    if let Backend::Ternary(t) = &mut l.backend {
                        t.set_interleave_lanes(lanes);
                    }
                }
            }
        };
        for_each(&mut m, Some(4));
        assert_eq!(m.simd_layers(), total);
        for_each(&mut m, None);
        assert_eq!(m.simd_layers(), 0, "stripped layouts must count zero");
    }

    #[test]
    fn layer_listing_complete() {
        let m = tiny_model(8);
        let layers = m.linear_layers();
        assert_eq!(layers.len(), m.config.n_layers * 7);
    }

    /// Sequential reference: decode tokens one at a time, collect the
    /// logits of the positions in `want`.
    fn sequential_logits(m: &Transformer, tokens: &[u32], want: &[usize]) -> Vec<Vec<f32>> {
        let mut cache = m.new_cache();
        let mut out = Vec::new();
        for (i, &t) in tokens.iter().enumerate() {
            let logits = m.decode_step(t, &mut cache);
            if want.contains(&i) {
                out.push(logits);
            }
        }
        out
    }

    #[test]
    fn forward_batch_chunk_bit_identical_to_decode_steps() {
        for quantized in [false, true] {
            let mut m = tiny_model(10);
            if quantized {
                m.quantize_with(&Ptqtp::default(), &crate::quant::QuantCtx::default());
            }
            let tokens = [3u32, 1, 4, 1, 5, 9, 2, 6];
            let expect = sequential_logits(&m, &tokens, &[5, 7]);

            let mut cache = m.new_cache();
            let mut scratch = m.new_scratch();
            let mut batch = ForwardBatch::new();
            for (i, &t) in tokens.iter().enumerate() {
                batch.push(t, i, 0, i == 5 || i == 7);
            }
            let n = m.forward_batch(&batch, &mut [&mut cache], &mut scratch);
            assert_eq!(n, 2);
            assert_eq!(cache.len(), tokens.len());
            assert_eq!(scratch.logits.row(0), expect[0].as_slice(), "q={quantized}");
            assert_eq!(scratch.logits.row(1), expect[1].as_slice(), "q={quantized}");
        }
    }

    #[test]
    fn forward_batch_multi_sequence_matches_sequential() {
        // two sequences at different depths + one fresh prefill chunk,
        // all fused into a single pass
        let m = tiny_model(11);
        let seq_a = [1u32, 7, 3];
        let seq_b = [9u32];

        // references, fully sequential
        let ea = sequential_logits(&m, &seq_a, &[2]).remove(0);
        let eb = sequential_logits(&m, &seq_b, &[0]).remove(0);

        // fused: seq A prefilled 2 tokens already, decodes its third;
        // seq B prefills its single token in the same batch
        let mut ca = m.new_cache();
        m.decode_step(seq_a[0], &mut ca);
        m.decode_step(seq_a[1], &mut ca);
        let mut cb = m.new_cache();
        let mut scratch = m.new_scratch();
        let mut batch = ForwardBatch::new();
        batch.push(seq_a[2], 2, 0, true);
        batch.push(seq_b[0], 0, 1, true);
        let n = m.forward_batch(&batch, &mut [&mut ca, &mut cb], &mut scratch);
        assert_eq!(n, 2);
        assert_eq!(scratch.logits.row(0), ea.as_slice());
        assert_eq!(scratch.logits.row(1), eb.as_slice());
        assert_eq!(ca.len(), 3);
        assert_eq!(cb.len(), 1);
    }

    #[test]
    fn prefill_matches_token_at_a_time() {
        let m = tiny_model(12);
        let tokens = [2u32, 4, 8, 1, 0, 3, 3, 7, 5];
        let expect = sequential_logits(&m, &tokens, &[tokens.len() - 1]).remove(0);
        let mut cache = m.new_cache();
        let mut scratch = m.new_scratch();
        // chunk=4 forces multiple ragged chunks
        let got = m.prefill(&tokens, &mut cache, &mut scratch, 4);
        assert_eq!(got, expect);
        assert_eq!(cache.len(), tokens.len());
    }

    #[test]
    fn decode_step_with_reuses_scratch() {
        let m = tiny_model(13);
        let mut c1 = m.new_cache();
        let mut c2 = m.new_cache();
        let mut scratch = m.new_scratch();
        for t in [1u32, 5, 9, 2] {
            let a = m.decode_step(t, &mut c1);
            let b = m.decode_step_with(t, &mut c2, &mut scratch).to_vec();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn threaded_exec_pool_bit_identical_on_eval_paths() {
        // set_threads must not change a single bit of the self-managed
        // passes (sequence NLL, greedy generation), quantized included
        for quantized in [false, true] {
            let mut m = tiny_model(15);
            if quantized {
                m.quantize_with(&Ptqtp::default(), &crate::quant::QuantCtx::default());
            }
            let tokens = [1u32, 5, 9, 2, 6, 3];
            let nll_seq = m.sequence_nll(&tokens);
            let gen_seq = m.generate_greedy(&[2, 4], 6, None);
            m.set_threads(3);
            assert_eq!(m.sequence_nll(&tokens), nll_seq, "q={quantized}");
            assert_eq!(m.generate_greedy(&[2, 4], 6, None), gen_seq, "q={quantized}");
        }
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let m = tiny_model(14);
        let mut scratch = m.new_scratch();
        let batch = ForwardBatch::new();
        let n = m.forward_batch(&batch, &mut [], &mut scratch);
        assert_eq!(n, 0);
        assert_eq!(scratch.logits.rows, 0);
    }
}

//! Quantization-aware linear layer: dense f32 or packed trit-planes.
//!
//! This is the switch point that makes the whole model servable in
//! PTQTP form — `Transformer::quantize` swaps every [`QuantLinear`]'s
//! backend in place, and the forward paths dispatch to the dense BLAS
//! substrate or the multiply-free ternary kernels.

use crate::quant::{QuantCtx, QuantRepr, Quantizer};
use crate::tensor::{ops, Matrix};
use crate::ternary::gemm::{gemm_packed_blocked_par_into, GemmScratch};
use crate::ternary::gemv::{gemv_packed, gemv_packed_par};
use crate::ternary::int_act;
use crate::ternary::linear::PackedTernaryLinear;
use crate::ternary::lut;
use crate::ternary::simd;

/// Weight backend.
#[derive(Clone, Debug)]
pub enum Backend {
    Dense(Matrix),
    Ternary(PackedTernaryLinear),
}

/// A linear layer `y = W·x` (no bias, LLaMA-style).
#[derive(Clone, Debug)]
pub struct QuantLinear {
    pub backend: Backend,
    /// (out_features, in_features)
    pub shape: (usize, usize),
}

impl QuantLinear {
    pub fn dense(w: Matrix) -> QuantLinear {
        let shape = (w.rows, w.cols);
        QuantLinear {
            backend: Backend::Dense(w),
            shape,
        }
    }

    /// Adopt a packed trit-plane backend directly (checkpoint load
    /// path: the planes come off disk already packed, so no densify and
    /// no requantize happens between quantization and serving). Builds
    /// the SIMD interleave if the reader didn't already (safety net for
    /// hand-constructed layers).
    pub fn from_packed(mut lin: PackedTernaryLinear) -> QuantLinear {
        lin.ensure_interleave();
        let shape = (lin.rows, lin.cols);
        QuantLinear {
            backend: Backend::Ternary(lin),
            shape,
        }
    }

    pub fn out_features(&self) -> usize {
        self.shape.0
    }

    pub fn in_features(&self) -> usize {
        self.shape.1
    }

    /// Decode-path forward: y = W·x for a single activation vector.
    pub fn forward_vec(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.shape.1);
        debug_assert_eq!(y.len(), self.shape.0);
        match &self.backend {
            Backend::Dense(w) => ops::matvec_into(w, x, y),
            Backend::Ternary(t) => gemv_packed(t, x, y),
        }
    }

    /// Decode-path forward with the int8-activation tier opt-in:
    /// eligible ternary layers (same gate as the batched dispatch)
    /// quantize `x` into `act` and run the scalar int sweep — which is
    /// `==`-exact to every other int-tier path — everything else falls
    /// through to [`QuantLinear::forward_vec`].
    pub fn forward_vec_act(
        &self,
        x: &[f32],
        y: &mut [f32],
        act_quant: bool,
        act: &mut int_act::IntActScratch,
    ) {
        if act_quant {
            if let Backend::Ternary(t) = &self.backend {
                if lut::is_aligned(t) && t.rows >= lut::LUT_MIN_ROWS {
                    act.prepare(x, t.group);
                    int_act::int_rows_span(t, &act.tables, &act.scales, 0..t.rows, y);
                    return;
                }
            }
        }
        self.forward_vec(x, y);
    }

    /// Batch forward: Y = X·Wᵀ (allocating convenience wrapper).
    ///
    /// Routed through [`QuantLinear::forward_rows_into`], so it is
    /// **bit-identical per row** to [`QuantLinear::forward_vec`] on
    /// both backends. It used to dispatch to throughput-tuned tiers
    /// with a different FP order — a footgun if a serving or eval path
    /// ever reached it; now every forward entry point shares the one
    /// bit-matched kernel family. Hot loops should still hold a
    /// [`GemmScratch`] and call `forward_rows_into` directly.
    pub fn forward_mat(&self, x: &Matrix) -> Matrix {
        let mut y = Matrix::zeros(x.rows, self.shape.0);
        let mut scratch = GemmScratch::new();
        self.forward_rows_into(x, &mut y, &mut scratch);
        y
    }

    /// Batched serving forward: Y = X·Wᵀ into a caller-owned output,
    /// zero steady-state allocation. Guaranteed **bit-identical per
    /// row** to [`QuantLinear::forward_vec`] on both backends, for any
    /// `scratch.pool` thread count and either `scratch.simd` setting:
    /// dense rows run the same matvec body (row-partitioned when the
    /// pool has lanes); ternary rows pick the fastest tier whose FP
    /// order mirrors `gemv_packed` exactly — the activation-indexed LUT
    /// kernels (SIMD row-blocked when the layer carries an interleaved
    /// layout) when the layout is byte-aligned and the matrix is tall
    /// enough to amortize the table build, else the SIMD packed kernel
    /// for aligned layouts below the LUT threshold, else the
    /// row-blocked packed kernel. This tier freedom is safe precisely
    /// because every tier is bit-identical; it is what makes the fused
    /// engine step produce token-for-token the same output as
    /// sequential decoding at any `--threads` and any `--simd`.
    pub fn forward_rows_into(&self, x: &Matrix, y: &mut Matrix, scratch: &mut GemmScratch) {
        debug_assert_eq!(x.cols, self.shape.1);
        debug_assert_eq!(y.rows, x.rows);
        debug_assert_eq!(y.cols, self.shape.0);
        match &self.backend {
            Backend::Dense(w) => ops::matvec_rows_pooled(w, x, y, &scratch.pool),
            Backend::Ternary(t) => {
                let use_lut = lut::is_aligned(t) && t.rows >= lut::LUT_MIN_ROWS;
                let il = if scratch.simd {
                    t.interleave.as_deref()
                } else {
                    None
                };
                // The int8-activation tier shares the LUT tier's gate
                // (table builds amortize identically); ragged or short
                // layers stay on the exact f32 tiers even when the
                // knob is on. Value-changing, so strictly opt-in via
                // `scratch.act_quant` (DESIGN.md §Integer-Kernels).
                if scratch.act_quant && use_lut {
                    if x.rows == 1 {
                        int_act::gemv_int_into(t, x.row(0), y.row_mut(0), scratch);
                    } else {
                        int_act::gemm_int_into(t, x, y, scratch);
                    }
                } else if x.rows == 1 {
                    if use_lut {
                        lut::gemv_lut_into(t, x.row(0), y.row_mut(0), scratch);
                    } else if let Some(il) = il {
                        let pool = scratch.pool.clone();
                        simd::gemv_packed_simd(t, il, x.row(0), y.row_mut(0), &pool);
                    } else {
                        let pool = scratch.pool.clone();
                        gemv_packed_par(t, x.row(0), y.row_mut(0), &pool);
                    }
                } else if use_lut {
                    lut::gemm_lut_into(t, x, y, scratch);
                } else if let Some(il) = il {
                    let pool = scratch.pool.clone();
                    simd::gemm_packed_simd(t, il, x, y, &pool);
                } else {
                    gemm_packed_blocked_par_into(t, x, y, scratch);
                }
            }
        }
    }

    /// Dense view of the weights (reconstructs if ternary).
    pub fn dense_weights(&self) -> Matrix {
        match &self.backend {
            Backend::Dense(w) => w.clone(),
            Backend::Ternary(t) => t.unpack().reconstruct(),
        }
    }

    /// Replace the backend by quantizing with `q`. PTQTP/absmean results
    /// keep their structured form (served multiply-free); grid methods
    /// store the dense reconstruction (fair: they'd be int-packed on
    /// real HW, but numerics are identical).
    ///
    /// Calibration handling: activation-aware methods need calibration
    /// whose width matches *this layer's* input dim; when the supplied
    /// ctx doesn't match (one ctx is shared across heterogeneous
    /// layers), a synthetic normal calibration of the right width is
    /// substituted so GPTQ/AWQ still exercise their activation paths.
    pub fn quantize_with(&mut self, q: &dyn Quantizer, ctx: &QuantCtx) {
        let w = self.dense_weights();
        let ctx_local;
        let ctx = match &ctx.calib {
            Some(c) if c.cols != self.shape.1 => {
                let mut rng = crate::rng::Rng::new(ctx.seed ^ self.shape.1 as u64);
                ctx_local = QuantCtx {
                    calib: Some(Matrix::randn(c.rows.max(16), self.shape.1, 1.0, &mut rng)),
                    seed: ctx.seed,
                    pool: ctx.pool.clone(),
                };
                &ctx_local
            }
            _ => ctx,
        };
        let r = q.quantize(&w, ctx);
        self.backend = match r.repr {
            QuantRepr::TritPlanes(lin) | QuantRepr::SinglePlane(lin) => {
                Backend::Ternary(lin.to_packed())
            }
            QuantRepr::Dense => Backend::Dense(r.w_hat),
        };
    }

    /// Resident weight bytes in the current backend.
    pub fn resident_bytes(&self) -> usize {
        match &self.backend {
            Backend::Dense(w) => w.len() * 4,
            Backend::Ternary(t) => t.resident_bytes(),
        }
    }

    pub fn is_ternary(&self) -> bool {
        matches!(self.backend, Backend::Ternary(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ptqtp::Ptqtp;
    use crate::rng::Rng;

    #[test]
    fn dense_forward_matches_matvec() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(16, 32, 0.1, &mut rng);
        let lin = QuantLinear::dense(w.clone());
        let x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; 16];
        lin.forward_vec(&x, &mut y);
        assert_eq!(y, ops::matvec(&w, &x));
    }

    #[test]
    fn quantize_with_ptqtp_switches_backend() {
        let mut rng = Rng::new(2);
        let w = Matrix::rand_heavy(16, 128, 0.03, &mut rng);
        let mut lin = QuantLinear::dense(w.clone());
        assert!(!lin.is_ternary());
        lin.quantize_with(&Ptqtp::default(), &QuantCtx::default());
        assert!(lin.is_ternary());
        // forward close to dense forward of reconstruction
        let x: Vec<f32> = (0..128).map(|_| rng.normal()).collect();
        let mut y_q = vec![0.0; 16];
        lin.forward_vec(&x, &mut y_q);
        let y_rec = ops::matvec(&lin.dense_weights(), &x);
        for (a, b) in y_q.iter().zip(&y_rec) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn ternary_resident_smaller_than_dense() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(64, 256, 0.05, &mut rng);
        let mut lin = QuantLinear::dense(w);
        let before = lin.resident_bytes();
        lin.quantize_with(&Ptqtp::default(), &QuantCtx::default());
        let after = lin.resident_bytes();
        assert!(after * 3 < before, "{after} vs {before}");
    }

    #[test]
    fn rows_path_bit_identical_to_vec_path() {
        // both backends: the batched kernel must equal per-row forward_vec
        // exactly (not just approximately) — engine parity depends on it
        let mut rng = Rng::new(7);
        let mut scratch = GemmScratch::new();
        for quantized in [false, true] {
            let w = Matrix::rand_heavy(12, 40, 0.05, &mut rng);
            let mut lin = QuantLinear::dense(w);
            if quantized {
                // G=10: ragged groups, G % 4 != 0
                lin.quantize_with(
                    &Ptqtp::new(crate::quant::ptqtp::PtqtpOpts {
                        group: 10,
                        ..Default::default()
                    }),
                    &QuantCtx::default(),
                );
            }
            let x = Matrix::randn(9, 40, 1.0, &mut rng);
            let mut ym = Matrix::zeros(9, 12);
            lin.forward_rows_into(&x, &mut ym, &mut scratch);
            for r in 0..9 {
                let mut yv = vec![0.0; 12];
                lin.forward_vec(x.row(r), &mut yv);
                assert_eq!(ym.row(r), yv.as_slice(), "quantized={quantized} row {r}");
            }
        }
    }

    #[test]
    fn rows_path_bit_identical_across_threads_and_tiers() {
        // aligned LUT tier (rows ≥ LUT_MIN_ROWS), ragged packed tier,
        // and dense — every (backend, threads) combo must equal per-row
        // forward_vec exactly
        use crate::quant::ptqtp::PtqtpOpts;
        use crate::threads::Pool;
        let mut rng = Rng::new(9);
        // both shapes clear the PAR_MIN_WORK dispatch gate for their
        // batch size, so the pool paths are genuinely exercised
        for (rows, cols, group, xrows) in [(560usize, 64usize, 16usize, 7usize), (70, 40, 10, 12)] {
            let w = Matrix::rand_heavy(rows, cols, 0.05, &mut rng);
            for quantized in [false, true] {
                let mut lin = QuantLinear::dense(w.clone());
                if quantized {
                    lin.quantize_with(
                        &Ptqtp::new(PtqtpOpts {
                            group,
                            ..Default::default()
                        }),
                        &QuantCtx::default(),
                    );
                }
                let x = Matrix::randn(xrows, cols, 1.0, &mut rng);
                let x1 = Matrix::from_vec(1, cols, x.row(0).to_vec());
                for threads in [1usize, 2, 4] {
                    let mut scratch = GemmScratch::new();
                    scratch.pool = Pool::new(threads);
                    let mut ym = Matrix::zeros(xrows, rows);
                    lin.forward_rows_into(&x, &mut ym, &mut scratch);
                    let mut y1 = Matrix::zeros(1, rows);
                    lin.forward_rows_into(&x1, &mut y1, &mut scratch);
                    for r in 0..xrows {
                        let mut yv = vec![0.0; rows];
                        lin.forward_vec(x.row(r), &mut yv);
                        assert_eq!(
                            ym.row(r),
                            yv.as_slice(),
                            "q={quantized} threads={threads} row {r} G={group}"
                        );
                        if r == 0 {
                            assert_eq!(
                                y1.row(0),
                                yv.as_slice(),
                                "single-row q={quantized} threads={threads} G={group}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn act_quant_ignored_where_ineligible_and_off_by_default() {
        // dense backends, ragged groups (G % 4 != 0), and short layers
        // (rows < LUT_MIN_ROWS) must produce bitwise-identical output
        // with the act_quant knob on or off; and a fresh scratch must
        // default to off so pre-existing outputs never change silently
        use crate::quant::ptqtp::PtqtpOpts;
        let mut rng = Rng::new(15);
        assert!(!GemmScratch::new().act_quant, "act_quant must default off");
        for (rows, group, quantize) in [
            (96usize, 10usize, true),
            (12, 8, true),
            (96, 8, false),
            (96, 8, true),
        ] {
            let mut lin = QuantLinear::dense(Matrix::rand_heavy(rows, 40, 0.05, &mut rng));
            if quantize {
                lin.quantize_with(
                    &Ptqtp::new(PtqtpOpts {
                        group,
                        ..Default::default()
                    }),
                    &QuantCtx::default(),
                );
            }
            let eligible = quantize && group % 4 == 0 && rows >= crate::ternary::lut::LUT_MIN_ROWS;
            let x = Matrix::randn(3, 40, 1.0, &mut rng);
            let mut y_off = Matrix::zeros(3, rows);
            let mut y_on = Matrix::zeros(3, rows);
            let mut scratch = GemmScratch::new();
            lin.forward_rows_into(&x, &mut y_off, &mut scratch);
            scratch.act_quant = true;
            lin.forward_rows_into(&x, &mut y_on, &mut scratch);
            if eligible {
                // 96×40 aligned layer genuinely switches tiers; the
                // quantized activations must actually change something
                // (guards against the gate silently never firing)
                assert_ne!(y_on.data, y_off.data, "rows={rows} G={group}");
            } else {
                assert_eq!(y_on.data, y_off.data, "rows={rows} G={group} q={quantize}");
            }
        }
    }

    #[test]
    fn empty_batch_through_threaded_scratch_is_noop() {
        // regression: a pure-prefill engine step can hand the LM head
        // zero logit rows; with a multi-lane pool this must be a no-op,
        // not an x.row(0) panic
        let mut rng = Rng::new(11);
        for quantized in [false, true] {
            let mut lin = QuantLinear::dense(Matrix::rand_heavy(96, 32, 0.05, &mut rng));
            if quantized {
                lin.quantize_with(&Ptqtp::default(), &QuantCtx::default());
            }
            let mut scratch = GemmScratch::new();
            scratch.pool = crate::threads::Pool::new(4);
            let x = Matrix::zeros(0, 32);
            let mut y = Matrix::zeros(0, 96);
            lin.forward_rows_into(&x, &mut y, &mut scratch);
            assert!(y.data.is_empty());
        }
    }

    #[test]
    fn mat_path_bit_identical_to_vec_path() {
        // forward_mat is routed through forward_rows_into, so it must
        // equal per-row forward_vec EXACTLY on both backends (the old
        // throughput-tuned dispatch was only approximately equal — the
        // documented footgun this guards against reintroducing)
        let mut rng = Rng::new(4);
        for quantized in [false, true] {
            let w = Matrix::rand_heavy(12, 64, 0.05, &mut rng);
            let mut lin = QuantLinear::dense(w);
            if quantized {
                lin.quantize_with(
                    &Ptqtp::new(crate::quant::ptqtp::PtqtpOpts {
                        group: 10, // ragged: G % 4 != 0
                        ..Default::default()
                    }),
                    &QuantCtx::default(),
                );
            }
            for rows in [1usize, 3, 10] {
                let x = Matrix::randn(rows, 64, 1.0, &mut rng);
                let ym = lin.forward_mat(&x);
                for r in 0..rows {
                    let mut yv = vec![0.0; 12];
                    lin.forward_vec(x.row(r), &mut yv);
                    assert_eq!(ym.row(r), yv.as_slice(), "q={quantized} rows={rows} r={r}");
                }
            }
        }
    }

    #[test]
    fn from_packed_preserves_kernel_output() {
        // moving the packed backend out and back in (what checkpoint
        // save/load does) must not change a single output bit
        let mut rng = Rng::new(5);
        let mut lin = QuantLinear::dense(Matrix::rand_heavy(16, 40, 0.05, &mut rng));
        lin.quantize_with(&Ptqtp::default(), &QuantCtx::default());
        let Backend::Ternary(packed) = &lin.backend else {
            panic!("expected ternary backend")
        };
        let lin2 = QuantLinear::from_packed(packed.clone());
        assert!(lin2.is_ternary());
        assert_eq!(lin2.shape, lin.shape);
        let x: Vec<f32> = (0..40).map(|_| rng.normal()).collect();
        let (mut a, mut b) = (vec![0.0; 16], vec![0.0; 16]);
        lin.forward_vec(&x, &mut a);
        lin2.forward_vec(&x, &mut b);
        assert_eq!(a, b);
    }
}

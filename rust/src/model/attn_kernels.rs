//! SIMD attention kernels over the head-major KV layout
//! (DESIGN.md §Attention-Kernels).
//!
//! [`attend_head`] is the whole per-(query-row, head) attention body —
//! score dots, softmax, weighted V-sum — over one KV head's contiguous
//! `[t × head_dim]` blocks. Two vectorization axes, chosen so every
//! lane replays the scalar floating-point fold order exactly:
//!
//! * **Scores** vectorize across lanes of 4/8 **consecutive cached
//!   positions**: each lane is an independent `dot(q, k_ti) · scale`
//!   replaying `ops::dot`'s 4-accumulator left fold, so lane `l`'s
//!   result is bitwise the scalar score of position `ti + l`. The
//!   head-major layout makes lane `l`'s key row the contiguous slice
//!   `keys[(ti + l)·hd ..]`.
//! * **The V-sum** vectorizes across **head-dim lanes**: for each
//!   position `ti` (in order), `out[i] += p · v[i]` over contiguous
//!   chunks of `i`. Every output element keeps its sequential fold
//!   over `ti` — the ops are elementwise, so any chunking of `i` is
//!   bitwise the scalar double loop.
//!
//! Lane width is the caller's dispatch decision (`lanes`: 1 = scalar
//! reference, 4 = portable row-block, 8 = AVX2 when detected, else the
//! portable 8-wide block): output is bitwise `==` for every choice —
//! the same parity discipline as `ternary::simd` — so the dispatcher
//! picks purely on speed and `--simd off` stays a perf-only knob.

use crate::tensor::ops::softmax_inplace;

/// Score/softmax/V-sum for one query head over `t` cached positions of
/// one KV head. `q` is the head's query (`hd` long); `keys`/`vals` are
/// the head's contiguous blocks (`≥ t·hd`); `out` (`hd` long) must be
/// zeroed — the V-sum accumulates into it. `scores` is caller scratch.
///
/// The paged attention path ([`super::attention`]) calls the two
/// stages separately — [`scores_into`] per page, one softmax, then
/// [`vsum_into`] per page — which is bitwise this function when the
/// chain is a single page.
#[allow(clippy::too_many_arguments)]
pub fn attend_head(
    q: &[f32],
    keys: &[f32],
    vals: &[f32],
    t: usize,
    hd: usize,
    scale: f32,
    lanes: usize,
    scores: &mut Vec<f32>,
    out: &mut [f32],
) {
    debug_assert!(keys.len() >= t * hd && vals.len() >= t * hd);
    scores.clear();
    scores.resize(t, 0.0);
    scores_into(q, keys, t, hd, scale, lanes, scores);
    softmax_inplace(scores);
    vsum_into(scores, vals, hd, lanes, out);
}

/// Stage 1: raw (pre-softmax) scores for `t` consecutive cached
/// positions of one KV head, written to `out[..t]` — `out[ti] =
/// dot(q, keys[ti·hd..]) · scale`, lane-vectorized in blocks with a
/// scalar tail. Each score is an independent dot, so computing a page's
/// scores into that page's sub-slice of the full score buffer is
/// bitwise the contiguous computation — the paged attend's stage-1
/// identity (DESIGN.md §Paged-KV).
pub fn scores_into(
    q: &[f32],
    keys: &[f32],
    t: usize,
    hd: usize,
    scale: f32,
    lanes: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(q.len(), hd);
    debug_assert!(keys.len() >= t * hd && out.len() >= t);
    // only 1/4/8 have kernels; anything else (rejected loudly by the
    // set_lanes setters) falls back to the scalar path rather than
    // mis-striding a block
    let lanes = match lanes {
        4 | 8 => lanes,
        _ => 1,
    };
    // ---- scores: lane blocks of consecutive positions, scalar tail ----
    let blocks = if lanes >= 4 { t / lanes } else { 0 };
    for b in 0..blocks {
        let ti = b * lanes;
        let kw = &keys[ti * hd..(ti + lanes) * hd];
        let ow = &mut out[ti..ti + lanes];
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        {
            if lanes == 8 && crate::ternary::simd::avx2_available() {
                // SAFETY: AVX2 presence just checked; `kw` holds the 8
                // contiguous position rows the gathers index.
                unsafe { x86::scores_block8(q, kw, hd, scale, ow) };
                continue;
            }
        }
        match lanes {
            8 => scores_block_portable::<8>(q, kw, hd, scale, ow),
            _ => scores_block_portable::<4>(q, kw, hd, scale, ow),
        }
    }
    for ti in blocks * lanes..t {
        out[ti] = crate::tensor::ops::dot(q, &keys[ti * hd..(ti + 1) * hd]) * scale;
    }
}

/// Stage 2: weighted V-sum — `out[i] += probs[ti] · vals[ti·hd + i]`
/// folded over `ti` in ascending order (`out` accumulates; callers
/// zero it first). The ops are elementwise with `ti` outermost, so
/// calling this once per page with that page's `probs` sub-slice, in
/// page order, replays the contiguous left fold exactly — the paged
/// attend's stage-2 identity (DESIGN.md §Paged-KV).
pub fn vsum_into(probs: &[f32], vals: &[f32], hd: usize, lanes: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), hd);
    debug_assert!(vals.len() >= probs.len() * hd);
    let lanes = match lanes {
        4 | 8 => lanes,
        _ => 1,
    };
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if lanes == 8 && crate::ternary::simd::avx2_available() {
            // SAFETY: AVX2 presence just checked; slice bounds asserted
            // above.
            unsafe { x86::vsum8(probs, vals, hd, out) };
            return;
        }
    }
    if lanes >= 4 {
        vsum_portable(probs, vals, hd, out);
    } else {
        for (ti, &p) in probs.iter().enumerate() {
            let vh = &vals[ti * hd..(ti + 1) * hd];
            for i in 0..hd {
                out[i] += p * vh[i];
            }
        }
    }
}

/// One N-position score block, portable form: per lane the exact
/// 4-accumulator fold of [`crate::tensor::ops::dot`] (s0..s3 over
/// 4-element chunks, `((s0+s1)+s2)+s3`, scalar tail), then `· scale` —
/// so lane `l` is bitwise `dot(q, keys[l·hd..]) · scale`.
fn scores_block_portable<const N: usize>(
    q: &[f32],
    keys: &[f32],
    hd: usize,
    scale: f32,
    out: &mut [f32],
) {
    let chunks = hd / 4;
    let mut s0 = [0.0f32; N];
    let mut s1 = [0.0f32; N];
    let mut s2 = [0.0f32; N];
    let mut s3 = [0.0f32; N];
    for c in 0..chunks {
        let i = c * 4;
        for l in 0..N {
            let k = &keys[l * hd + i..l * hd + i + 4];
            s0[l] += q[i] * k[0];
            s1[l] += q[i + 1] * k[1];
            s2[l] += q[i + 2] * k[2];
            s3[l] += q[i + 3] * k[3];
        }
    }
    let mut s = [0.0f32; N];
    for l in 0..N {
        s[l] = s0[l] + s1[l] + s2[l] + s3[l];
    }
    for i in chunks * 4..hd {
        for l in 0..N {
            s[l] += q[i] * keys[l * hd + i];
        }
    }
    for l in 0..N {
        out[l] = s[l] * scale;
    }
}

/// Weighted V-sum, portable 4-wide head-dim chunks. Elementwise mul +
/// add per (ti, i) with `ti` outermost — bitwise the scalar double
/// loop for any chunking of `i`.
fn vsum_portable(probs: &[f32], vals: &[f32], hd: usize, out: &mut [f32]) {
    let chunks = hd / 4;
    for (ti, &p) in probs.iter().enumerate() {
        let vh = &vals[ti * hd..(ti + 1) * hd];
        for c in 0..chunks {
            let i = c * 4;
            out[i] += p * vh[i];
            out[i + 1] += p * vh[i + 1];
            out[i + 2] += p * vh[i + 2];
            out[i + 3] += p * vh[i + 3];
        }
        for i in chunks * 4..hd {
            out[i] += p * vh[i];
        }
    }
}

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
mod x86 {
    //! 8-lane AVX2 attention kernels. Bit-identity argument: every
    //! vector op is the lanewise IEEE operation the scalar body issues
    //! (`vmulps`/`vaddps`, no FMA contraction — Rust never contracts),
    //! gathers load exact key bits at stride `hd`, and accumulator
    //! structure + fold order replicate `ops::dot` / the scalar V-sum
    //! per lane exactly.
    #[cfg(target_arch = "x86")]
    use core::arch::x86::*;
    #[cfg(target_arch = "x86_64")]
    use core::arch::x86_64::*;

    /// 8 consecutive position dots: lane `l` gathers `keys[l·hd + i]`
    /// and replays `ops::dot`'s s0..s3 accumulator fold.
    ///
    /// Safety: caller verified AVX2; `keys` holds `8·hd` floats.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn scores_block8(
        q: &[f32],
        keys: &[f32],
        hd: usize,
        scale: f32,
        out: &mut [f32],
    ) {
        debug_assert!(keys.len() >= 8 * hd && out.len() == 8);
        let base = keys.as_ptr();
        // element index of lane l at chunk offset i is i + l·hd
        let lane_off = _mm256_mullo_epi32(
            _mm256_set1_epi32(hd as i32),
            _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7),
        );
        let mut s0 = _mm256_setzero_ps();
        let mut s1 = _mm256_setzero_ps();
        let mut s2 = _mm256_setzero_ps();
        let mut s3 = _mm256_setzero_ps();
        let chunks = hd / 4;
        for c in 0..chunks {
            let i = c * 4;
            let k0 = _mm256_i32gather_ps::<4>(base.add(i), lane_off);
            let k1 = _mm256_i32gather_ps::<4>(base.add(i + 1), lane_off);
            let k2 = _mm256_i32gather_ps::<4>(base.add(i + 2), lane_off);
            let k3 = _mm256_i32gather_ps::<4>(base.add(i + 3), lane_off);
            s0 = _mm256_add_ps(s0, _mm256_mul_ps(_mm256_set1_ps(q[i]), k0));
            s1 = _mm256_add_ps(s1, _mm256_mul_ps(_mm256_set1_ps(q[i + 1]), k1));
            s2 = _mm256_add_ps(s2, _mm256_mul_ps(_mm256_set1_ps(q[i + 2]), k2));
            s3 = _mm256_add_ps(s3, _mm256_mul_ps(_mm256_set1_ps(q[i + 3]), k3));
        }
        // ((s0 + s1) + s2) + s3 — the exact dot() reduction order
        let mut s = _mm256_add_ps(_mm256_add_ps(_mm256_add_ps(s0, s1), s2), s3);
        for i in chunks * 4..hd {
            let kv = _mm256_i32gather_ps::<4>(base.add(i), lane_off);
            s = _mm256_add_ps(s, _mm256_mul_ps(_mm256_set1_ps(q[i]), kv));
        }
        _mm256_storeu_ps(out.as_mut_ptr(), _mm256_mul_ps(s, _mm256_set1_ps(scale)));
    }

    /// Weighted V-sum over 8-wide head-dim chunks: contiguous loads of
    /// `v`, broadcast `p`, mul then add (never fused) — per element the
    /// scalar `out[i] += p · v[i]` in the same `ti` order.
    ///
    /// Safety: caller verified AVX2; `vals` holds `t·hd` floats and
    /// `out` holds `hd`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn vsum8(probs: &[f32], vals: &[f32], hd: usize, out: &mut [f32]) {
        debug_assert!(vals.len() >= probs.len() * hd && out.len() == hd);
        let chunks = hd / 8;
        for (ti, &p) in probs.iter().enumerate() {
            let v = vals.as_ptr().add(ti * hd);
            let pv = _mm256_set1_ps(p);
            for c in 0..chunks {
                let o = out.as_mut_ptr().add(c * 8);
                let cur = _mm256_loadu_ps(o);
                let vv = _mm256_loadu_ps(v.add(c * 8));
                _mm256_storeu_ps(o, _mm256_add_ps(cur, _mm256_mul_ps(pv, vv)));
            }
            for i in chunks * 8..hd {
                out[i] += p * *v.add(i);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Scalar reference: the exact pre-tier attention body.
    fn attend_ref(
        q: &[f32],
        keys: &[f32],
        vals: &[f32],
        t: usize,
        hd: usize,
        scale: f32,
    ) -> Vec<f32> {
        let mut scores = vec![0.0f32; t];
        for ti in 0..t {
            scores[ti] = crate::tensor::ops::dot(q, &keys[ti * hd..(ti + 1) * hd]) * scale;
        }
        softmax_inplace(&mut scores);
        let mut out = vec![0.0f32; hd];
        for (ti, &p) in scores.iter().enumerate() {
            let vh = &vals[ti * hd..(ti + 1) * hd];
            for i in 0..hd {
                out[i] += p * vh[i];
            }
        }
        out
    }

    #[test]
    fn lane_widths_bit_identical_to_scalar() {
        let mut rng = Rng::new(5);
        // t covers: < lanes (all tail), lane multiples, ragged tails;
        // hd covers 4-chunk-exact and ragged head dims
        for &hd in &[4usize, 10, 12, 64] {
            for &t in &[1usize, 3, 4, 8, 17, 64, 257] {
                let q: Vec<f32> = (0..hd).map(|_| rng.normal()).collect();
                let keys: Vec<f32> = (0..t * hd).map(|_| rng.normal()).collect();
                let vals: Vec<f32> = (0..t * hd).map(|_| rng.normal()).collect();
                let scale = 1.0 / (hd as f32).sqrt();
                let expect = attend_ref(&q, &keys, &vals, t, hd, scale);
                let mut scores = Vec::new();
                for &lanes in &[1usize, 4, 8] {
                    let mut out = vec![0.0f32; hd];
                    attend_head(&q, &keys, &vals, t, hd, scale, lanes, &mut scores, &mut out);
                    assert_eq!(out, expect, "hd={hd} t={t} lanes={lanes}");
                }
            }
        }
    }

    #[test]
    fn paged_stage_split_bit_identical_to_fused() {
        // computing scores per page-chunk into sub-slices, one softmax,
        // then per-chunk V-sums in order must be bitwise attend_head
        let mut rng = Rng::new(11);
        let hd = 12;
        let scale = 1.0 / (hd as f32).sqrt();
        for &t in &[5usize, 16, 33] {
            for &page in &[4usize, 8, 64] {
                let q: Vec<f32> = (0..hd).map(|_| rng.normal()).collect();
                let keys: Vec<f32> = (0..t * hd).map(|_| rng.normal()).collect();
                let vals: Vec<f32> = (0..t * hd).map(|_| rng.normal()).collect();
                for &lanes in &[1usize, 4, 8] {
                    let mut scores = Vec::new();
                    let mut expect = vec![0.0f32; hd];
                    attend_head(&q, &keys, &vals, t, hd, scale, lanes, &mut scores, &mut expect);

                    let mut ps = vec![0.0f32; t];
                    let mut base = 0;
                    while base < t {
                        let fill = page.min(t - base);
                        scores_into(
                            &q,
                            &keys[base * hd..(base + fill) * hd],
                            fill,
                            hd,
                            scale,
                            lanes,
                            &mut ps[base..base + fill],
                        );
                        base += fill;
                    }
                    softmax_inplace(&mut ps);
                    let mut out = vec![0.0f32; hd];
                    let mut base = 0;
                    while base < t {
                        let fill = page.min(t - base);
                        vsum_into(
                            &ps[base..base + fill],
                            &vals[base * hd..(base + fill) * hd],
                            hd,
                            lanes,
                            &mut out,
                        );
                        base += fill;
                    }
                    assert_eq!(out, expect, "t={t} page={page} lanes={lanes}");
                }
            }
        }
    }

    #[test]
    fn accumulates_into_out() {
        // out must be accumulated (callers zero it); seeding out shifts
        // the result by exactly the seed
        let mut rng = Rng::new(9);
        let hd = 8;
        let t = 5;
        let q: Vec<f32> = (0..hd).map(|_| rng.normal()).collect();
        let keys: Vec<f32> = (0..t * hd).map(|_| rng.normal()).collect();
        let vals: Vec<f32> = (0..t * hd).map(|_| rng.normal()).collect();
        let mut scores = Vec::new();
        let mut base = vec![0.0f32; hd];
        attend_head(&q, &keys, &vals, t, hd, 0.5, 1, &mut scores, &mut base);
        let mut seeded = vec![1.0f32; hd];
        attend_head(&q, &keys, &vals, t, hd, 0.5, 1, &mut scores, &mut seeded);
        for i in 0..hd {
            assert!((seeded[i] - base[i] - 1.0).abs() < 1e-6);
        }
    }
}

//! RMSNorm (Zhang & Sennrich) — the LLaMA-family pre-norm.

/// RMS normalization with learned gain.
#[derive(Clone, Debug)]
pub struct RmsNorm {
    pub weight: Vec<f32>,
    pub eps: f32,
}

impl RmsNorm {
    pub fn new(weight: Vec<f32>, eps: f32) -> RmsNorm {
        RmsNorm { weight, eps }
    }

    pub fn ones(dim: usize, eps: f32) -> RmsNorm {
        RmsNorm {
            weight: vec![1.0; dim],
            eps,
        }
    }

    /// out[i] = w[i] · x[i] / rms(x)
    pub fn forward(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.weight.len());
        let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64;
        let inv = 1.0 / (ms + self.eps as f64).sqrt() as f32;
        for i in 0..x.len() {
            out[i] = self.weight[i] * x[i] * inv;
        }
    }

    /// In-place variant.
    pub fn forward_inplace(&self, x: &mut [f32]) {
        let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64;
        let inv = 1.0 / (ms + self.eps as f64).sqrt() as f32;
        for (i, v) in x.iter_mut().enumerate() {
            *v = self.weight[i] * *v * inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_rms_output() {
        let n = RmsNorm::ones(4, 1e-6);
        let x = [2.0f32, -2.0, 2.0, -2.0];
        let mut out = [0.0f32; 4];
        n.forward(&x, &mut out);
        let rms: f32 = (out.iter().map(|v| v * v).sum::<f32>() / 4.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-4);
    }

    #[test]
    fn gain_applied() {
        let n = RmsNorm::new(vec![2.0, 0.0], 1e-6);
        let mut out = [0.0f32; 2];
        n.forward(&[1.0, 1.0], &mut out);
        assert!((out[0] - 2.0).abs() < 1e-5);
        assert_eq!(out[1], 0.0);
    }

    #[test]
    fn eps_guards_zero_input() {
        let n = RmsNorm::ones(3, 1e-6);
        let mut out = [0.0f32; 3];
        n.forward(&[0.0, 0.0, 0.0], &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn inplace_matches() {
        let n = RmsNorm::new(vec![1.5, -0.5, 2.0], 1e-5);
        let x = [0.3f32, -1.2, 0.7];
        let mut a = [0.0f32; 3];
        n.forward(&x, &mut a);
        let mut b = x;
        n.forward_inplace(&mut b);
        assert_eq!(a, b);
    }
}

//! KV cache for one sequence: per layer, append-only K/V buffers in a
//! **head-major** layout.
//!
//! Each (layer, kv-head) pair owns a contiguous `[len × head_dim]`
//! block, so every attention kernel streams unit-stride memory: with
//! GQA, all `n_heads / n_kv_heads` query heads sharing a KV head read
//! the *same* contiguous block instead of `kv_dim`-strided slices of a
//! position-interleaved buffer (DESIGN.md §Attention-Kernels has the
//! byte-offset diagram and the bandwidth math).
//!
//! The serving engine pools these (see `coordinator::kv_pool` for the
//! bounded recycling pool); this type is the per-sequence view the
//! attention kernels consume.

/// Recoverable full-cache signal: an append was requested past
/// `max_seq`. Surfaced by [`KvCache::try_append`] so the serving
/// engine can turn capacity exhaustion into a per-request error or
/// truncation instead of a replica-killing panic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheFull {
    pub max_seq: usize,
}

impl std::fmt::Display for CacheFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KV cache full (max_seq={})", self.max_seq)
    }
}

impl std::error::Error for CacheFull {}

/// Append-only cache for all layers of one sequence, head-major.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    /// k[layer · n_kv_heads + kvh] is a contiguous (len · head_dim)
    /// block: position `ti`'s key for that head lives at
    /// `[ti · head_dim .. (ti + 1) · head_dim]`.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    len: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, n_kv_heads: usize, head_dim: usize, max_seq: usize) -> KvCache {
        let blocks = n_layers * n_kv_heads;
        KvCache {
            n_layers,
            n_kv_heads,
            head_dim,
            max_seq,
            k: (0..blocks).map(|_| Vec::with_capacity(max_seq * head_dim)).collect(),
            v: (0..blocks).map(|_| Vec::with_capacity(max_seq * head_dim)).collect(),
            len: 0,
        }
    }

    /// Width of one position's K (or V) across all KV heads.
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Number of cached (committed) positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.max_seq
    }

    /// Committed positions still available before the cache is full.
    pub fn remaining(&self) -> usize {
        self.max_seq - self.len.min(self.max_seq)
    }

    #[inline]
    fn block(&self, layer: usize, kvh: usize) -> usize {
        debug_assert!(layer < self.n_layers && kvh < self.n_kv_heads);
        layer * self.n_kv_heads + kvh
    }

    /// Append one position's K/V for layer `layer` (`k`/`v` are
    /// `kv_dim` long, `[head0 | head1 | ...]`); each head's chunk goes
    /// to that head's contiguous block. Multiple positions may be
    /// staged per layer before a single [`KvCache::commit_n`] (the
    /// batched prefill path); the classic decode path appends one
    /// position per layer then calls [`KvCache::commit`]. Staged
    /// (uncommitted) positions are already visible through
    /// [`KvCache::keys`]/[`KvCache::values`], which is what lets a
    /// prefill chunk attend to itself causally.
    ///
    /// Panics on overflow — callers that plan capacity (the engine)
    /// guard with [`KvCache::remaining`] or use
    /// [`KvCache::try_append`] for the recoverable form.
    pub fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        if let Err(e) = self.try_append(layer, k, v) {
            panic!("KV cache overflow ({e})");
        }
    }

    /// [`KvCache::append`] returning the recoverable [`CacheFull`]
    /// signal instead of panicking; the cache is unchanged on `Err`.
    pub fn try_append(&mut self, layer: usize, k: &[f32], v: &[f32]) -> Result<(), CacheFull> {
        debug_assert_eq!(k.len(), self.kv_dim());
        debug_assert_eq!(v.len(), self.kv_dim());
        if self.staged_len(layer) >= self.max_seq {
            return Err(CacheFull {
                max_seq: self.max_seq,
            });
        }
        let hd = self.head_dim;
        for kvh in 0..self.n_kv_heads {
            let b = self.block(layer, kvh);
            self.k[b].extend_from_slice(&k[kvh * hd..(kvh + 1) * hd]);
            self.v[b].extend_from_slice(&v[kvh * hd..(kvh + 1) * hd]);
        }
        Ok(())
    }

    /// Staged positions for `layer`: committed length plus any appends
    /// not yet committed.
    pub fn staged_len(&self, layer: usize) -> usize {
        self.k[layer * self.n_kv_heads].len() / self.head_dim
    }

    /// Advance the position counter after all layers appended.
    pub fn commit(&mut self) {
        self.commit_n(1);
    }

    /// Advance the position counter by `n` after every layer received
    /// `n` staged appends (the batched forward path commits a whole
    /// prefill chunk at once).
    pub fn commit_n(&mut self, n: usize) {
        self.len += n;
        for b in 0..self.n_layers * self.n_kv_heads {
            debug_assert_eq!(self.k[b].len(), self.len * self.head_dim);
            debug_assert_eq!(self.v[b].len(), self.len * self.head_dim);
        }
    }

    /// K block for one (layer, kv-head): `staged · head_dim` values,
    /// unit-stride — position `ti`'s key is `[ti·hd .. (ti+1)·hd]`.
    pub fn keys(&self, layer: usize, kvh: usize) -> &[f32] {
        &self.k[self.block(layer, kvh)]
    }

    pub fn values(&self, layer: usize, kvh: usize) -> &[f32] {
        &self.v[self.block(layer, kvh)]
    }

    /// Drop all cached state but keep capacity (sequence reuse).
    pub fn reset(&mut self) {
        for buf in self.k.iter_mut().chain(self.v.iter_mut()) {
            buf.clear();
        }
        self.len = 0;
    }

    /// Truncate to the first `keep` positions (speculative rollback).
    pub fn truncate(&mut self, keep: usize) {
        let keep = keep.min(self.len);
        for buf in self.k.iter_mut().chain(self.v.iter_mut()) {
            buf.truncate(keep * self.head_dim);
        }
        self.len = keep;
    }

    /// Resident bytes.
    pub fn bytes(&self) -> usize {
        self.k
            .iter()
            .chain(self.v.iter())
            .map(|b| b.capacity() * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_commit_cycle() {
        // 2 layers, 2 kv-heads × head_dim 2 (kv_dim 4)
        let mut c = KvCache::new(2, 2, 2, 8);
        for step in 0..3 {
            for layer in 0..2 {
                let k = vec![step as f32; 4];
                let v = vec![-(step as f32); 4];
                c.append(layer, &k, &v);
            }
            c.commit();
        }
        assert_eq!(c.len(), 3);
        assert_eq!(c.kv_dim(), 4);
        // per-head blocks hold len × head_dim values each
        assert_eq!(c.keys(0, 0).len(), 6);
        assert_eq!(c.keys(1, 1).len(), 6);
        assert_eq!(c.keys(1, 0)[4], 2.0);
        assert_eq!(c.values(1, 1)[4], -2.0);
    }

    #[test]
    fn head_major_blocks_are_contiguous_per_head() {
        // distinct per-head values must land in distinct contiguous blocks
        let mut c = KvCache::new(1, 3, 2, 4);
        for pos in 0..3 {
            // head h carries value 10·h + pos
            let k: Vec<f32> = (0..3)
                .flat_map(|h| [(10 * h + pos) as f32; 2])
                .collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            c.append(0, &k, &v);
            c.commit();
        }
        for h in 0..3 {
            let block = c.keys(0, h);
            assert_eq!(block.len(), 6);
            for pos in 0..3 {
                // position ti of head h is the unit-stride slice [ti·hd..]
                assert_eq!(block[pos * 2], (10 * h + pos) as f32);
                assert_eq!(block[pos * 2 + 1], (10 * h + pos) as f32);
                assert_eq!(c.values(0, h)[pos * 2], -((10 * h + pos) as f32));
            }
        }
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1, 2, 1);
        c.append(0, &[0.0, 0.0], &[0.0, 0.0]);
        c.commit();
        c.append(0, &[1.0, 1.0], &[1.0, 1.0]);
    }

    #[test]
    fn try_append_surfaces_recoverable_cache_full() {
        let mut c = KvCache::new(1, 1, 2, 1);
        assert_eq!(c.remaining(), 1);
        assert!(c.try_append(0, &[0.0, 0.0], &[0.0, 0.0]).is_ok());
        c.commit();
        assert_eq!(c.remaining(), 0);
        // no panic: the full cache reports a typed, recoverable error
        let err = c.try_append(0, &[1.0, 1.0], &[1.0, 1.0]).unwrap_err();
        assert_eq!(err, CacheFull { max_seq: 1 });
        assert!(err.to_string().contains("max_seq=1"));
        // and the cache is unchanged — still servable
        assert_eq!(c.len(), 1);
        assert_eq!(c.keys(0, 0), &[0.0, 0.0]);
    }

    #[test]
    fn truncate_rolls_back() {
        let mut c = KvCache::new(1, 1, 2, 8);
        for i in 0..4 {
            c.append(0, &[i as f32, 0.0], &[0.0, 0.0]);
            c.commit();
        }
        c.truncate(2);
        assert_eq!(c.len(), 2);
        assert_eq!(c.keys(0, 0).len(), 4);
        // can append again
        c.append(0, &[9.0, 9.0], &[0.0, 0.0]);
        c.commit();
        assert_eq!(c.keys(0, 0)[4], 9.0);
    }

    #[test]
    fn multi_append_then_commit_n() {
        // batched prefill: stage a whole chunk per layer, commit once
        let mut c = KvCache::new(2, 1, 2, 8);
        for layer in 0..2 {
            for p in 0..3 {
                c.append(layer, &[p as f32, 0.0], &[0.0, p as f32]);
            }
            assert_eq!(c.staged_len(layer), 3);
        }
        assert_eq!(c.len(), 0, "not yet committed");
        // staged K/V already visible (prefill chunk self-attention)
        assert_eq!(c.keys(0, 0).len(), 6);
        assert_eq!(c.keys(1, 0)[4], 2.0);
        c.commit_n(3);
        assert_eq!(c.len(), 3);
        // and the cache keeps working with classic single commits
        c.append(0, &[9.0, 9.0], &[0.0, 0.0]);
        c.append(1, &[9.0, 9.0], &[0.0, 0.0]);
        c.commit();
        assert_eq!(c.len(), 4);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn staged_overflow_panics() {
        let mut c = KvCache::new(1, 1, 2, 2);
        c.append(0, &[0.0; 2], &[0.0; 2]);
        c.append(0, &[0.0; 2], &[0.0; 2]);
        c.append(0, &[0.0; 2], &[0.0; 2]); // third staged position > max_seq
    }

    #[test]
    fn reset_reuses() {
        let mut c = KvCache::new(1, 1, 2, 4);
        c.append(0, &[1.0, 1.0], &[1.0, 1.0]);
        c.commit();
        c.reset();
        assert!(c.is_empty());
        assert!(!c.is_full());
        assert_eq!(c.remaining(), 4);
    }
}

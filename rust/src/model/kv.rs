//! KV cache for one sequence: per layer, append-only K/V buffers.
//!
//! The serving engine pools these (see `coordinator::kv_cache` for the
//! paged pool with ref-counting); this type is the per-sequence view
//! the attention kernel consumes.

/// Append-only cache for all layers of one sequence.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub n_layers: usize,
    pub kv_dim: usize,
    pub max_seq: usize,
    /// k[layer] is a flat (len · kv_dim) buffer.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    len: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, kv_dim: usize, max_seq: usize) -> KvCache {
        KvCache {
            n_layers,
            kv_dim,
            max_seq,
            k: (0..n_layers).map(|_| Vec::with_capacity(max_seq * kv_dim)).collect(),
            v: (0..n_layers).map(|_| Vec::with_capacity(max_seq * kv_dim)).collect(),
            len: 0,
        }
    }

    /// Number of cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.max_seq
    }

    /// Append one position's K/V for layer `layer`. Multiple positions
    /// may be staged per layer before a single [`KvCache::commit_n`]
    /// (the batched prefill path); the classic decode path appends one
    /// position per layer then calls [`KvCache::commit`]. Staged
    /// (uncommitted) positions are already visible through
    /// [`KvCache::keys`]/[`KvCache::values`], which is what lets a
    /// prefill chunk attend to itself causally.
    pub fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.kv_dim);
        debug_assert_eq!(v.len(), self.kv_dim);
        assert!(
            self.k[layer].len() < self.max_seq * self.kv_dim,
            "KV cache overflow (max_seq={})",
            self.max_seq
        );
        self.k[layer].extend_from_slice(k);
        self.v[layer].extend_from_slice(v);
    }

    /// Staged positions for `layer`: committed length plus any appends
    /// not yet committed.
    pub fn staged_len(&self, layer: usize) -> usize {
        self.k[layer].len() / self.kv_dim
    }

    /// Advance the position counter after all layers appended.
    pub fn commit(&mut self) {
        self.commit_n(1);
    }

    /// Advance the position counter by `n` after every layer received
    /// `n` staged appends (the batched forward path commits a whole
    /// prefill chunk at once).
    pub fn commit_n(&mut self, n: usize) {
        self.len += n;
        for layer in 0..self.n_layers {
            debug_assert_eq!(self.k[layer].len(), self.len * self.kv_dim);
            debug_assert_eq!(self.v[layer].len(), self.len * self.kv_dim);
        }
    }

    /// K buffer for a layer: `len · kv_dim` values.
    pub fn keys(&self, layer: usize) -> &[f32] {
        &self.k[layer]
    }

    pub fn values(&self, layer: usize) -> &[f32] {
        &self.v[layer]
    }

    /// Drop all cached state but keep capacity (sequence reuse).
    pub fn reset(&mut self) {
        for buf in self.k.iter_mut().chain(self.v.iter_mut()) {
            buf.clear();
        }
        self.len = 0;
    }

    /// Truncate to the first `keep` positions (speculative rollback).
    pub fn truncate(&mut self, keep: usize) {
        let keep = keep.min(self.len);
        for buf in self.k.iter_mut().chain(self.v.iter_mut()) {
            buf.truncate(keep * self.kv_dim);
        }
        self.len = keep;
    }

    /// Resident bytes.
    pub fn bytes(&self) -> usize {
        self.k
            .iter()
            .chain(self.v.iter())
            .map(|b| b.capacity() * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_commit_cycle() {
        let mut c = KvCache::new(2, 4, 8);
        for step in 0..3 {
            for layer in 0..2 {
                let k = vec![step as f32; 4];
                let v = vec![-(step as f32); 4];
                c.append(layer, &k, &v);
            }
            c.commit();
        }
        assert_eq!(c.len(), 3);
        assert_eq!(c.keys(0).len(), 12);
        assert_eq!(c.keys(1)[8], 2.0);
        assert_eq!(c.values(1)[8], -2.0);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 2, 1);
        c.append(0, &[0.0, 0.0], &[0.0, 0.0]);
        c.commit();
        c.append(0, &[1.0, 1.0], &[1.0, 1.0]);
    }

    #[test]
    fn truncate_rolls_back() {
        let mut c = KvCache::new(1, 2, 8);
        for i in 0..4 {
            c.append(0, &[i as f32, 0.0], &[0.0, 0.0]);
            c.commit();
        }
        c.truncate(2);
        assert_eq!(c.len(), 2);
        assert_eq!(c.keys(0).len(), 4);
        // can append again
        c.append(0, &[9.0, 9.0], &[0.0, 0.0]);
        c.commit();
        assert_eq!(c.keys(0)[4], 9.0);
    }

    #[test]
    fn multi_append_then_commit_n() {
        // batched prefill: stage a whole chunk per layer, commit once
        let mut c = KvCache::new(2, 2, 8);
        for layer in 0..2 {
            for p in 0..3 {
                c.append(layer, &[p as f32, 0.0], &[0.0, p as f32]);
            }
            assert_eq!(c.staged_len(layer), 3);
        }
        assert_eq!(c.len(), 0, "not yet committed");
        // staged K/V already visible (prefill chunk self-attention)
        assert_eq!(c.keys(0).len(), 6);
        assert_eq!(c.keys(1)[4], 2.0);
        c.commit_n(3);
        assert_eq!(c.len(), 3);
        // and the cache keeps working with classic single commits
        c.append(0, &[9.0, 9.0], &[0.0, 0.0]);
        c.append(1, &[9.0, 9.0], &[0.0, 0.0]);
        c.commit();
        assert_eq!(c.len(), 4);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn staged_overflow_panics() {
        let mut c = KvCache::new(1, 2, 2);
        c.append(0, &[0.0; 2], &[0.0; 2]);
        c.append(0, &[0.0; 2], &[0.0; 2]);
        c.append(0, &[0.0; 2], &[0.0; 2]); // third staged position > max_seq
    }

    #[test]
    fn reset_reuses() {
        let mut c = KvCache::new(1, 2, 4);
        c.append(0, &[1.0, 1.0], &[1.0, 1.0]);
        c.commit();
        c.reset();
        assert!(c.is_empty());
        assert!(!c.is_full());
    }
}

//! Paged KV cache: per (layer, kv-head) **head-major** streams stored
//! as chains of fixed-size, refcounted pages.
//!
//! Each page ([`KvPage`]) holds `page_size` cached positions for *all*
//! (layer, kv-head) blocks of one sequence segment: within a page, the
//! block for (layer, kvh) is the contiguous `[page_size × head_dim]`
//! slice starting at `(layer · n_kv_heads + kvh) · page_size ·
//! head_dim`, and position `pos` lives at offset `(pos % page_size) ·
//! head_dim` inside it. The page table is simply `pages[pos /
//! page_size]`. Within a page every attention kernel still streams
//! unit-stride memory exactly as the contiguous PR-5 layout did
//! (DESIGN.md §Paged-KV has the byte-offset diagram).
//!
//! Pages are `Arc`-refcounted so multiple sequences can share a
//! physical prefix (the radix prefix cache in
//! `coordinator::prefix_cache` hands out extra references). Writes go
//! through copy-on-write: a cache only ever mutates a page it holds
//! exclusively, cloning the payload first when the page is shared.
//!
//! All pages come from a shared [`PageStore`] — a free-list pool sized
//! in pages with an optional budget, so a serving replica bounds its
//! total KV memory across sequences rather than per sequence. The
//! legacy single-allocation behavior is preserved exactly by
//! [`KvCache::new`], which builds a one-page cache (`page_size =
//! max_seq`) over a private unbounded store.

use std::sync::{Arc, Mutex, Weak};

/// Recoverable full-cache signal: an append was requested past
/// `max_seq`. Surfaced by [`KvCache::try_append`] so the serving
/// engine can turn capacity exhaustion into a per-request error or
/// truncation instead of a replica-killing panic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheFull {
    pub max_seq: usize,
}

impl std::fmt::Display for CacheFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KV cache full (max_seq={})", self.max_seq)
    }
}

impl std::error::Error for CacheFull {}

/// Recoverable page-pool exhaustion: a [`KvCache::reserve`] could not
/// allocate because the shared [`PageStore`] hit its page budget. The
/// serving engine turns this into preemption (release a victim's pages,
/// re-enqueue it for recompute) instead of failing the request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PagesExhausted {
    /// The store's page budget at the time of the failed allocation.
    pub budget: usize,
}

impl std::fmt::Display for PagesExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KV page pool exhausted (budget={} pages)", self.budget)
    }
}

impl std::error::Error for PagesExhausted {}

/// One fixed-size page of cached positions for every (layer, kv-head)
/// block of a sequence segment. `k`/`v` are `n_layers · n_kv_heads ·
/// page_size · head_dim` floats; see the module docs for the offset
/// math. Shared between sequences via `Arc` — mutation is only allowed
/// through [`KvCache`]'s copy-on-write path.
#[derive(Debug)]
pub struct KvPage {
    pub(crate) k: Box<[f32]>,
    pub(crate) v: Box<[f32]>,
    /// Back-reference to the allocating store for `Drop` accounting.
    /// Weak so outstanding pages never keep a dead store alive.
    store: Weak<Mutex<StoreInner>>,
}

impl Drop for KvPage {
    /// Deallocation accounting lives HERE, on the last strong-ref drop,
    /// not in [`PageStore::release`]: `Arc` guarantees exactly one
    /// `Drop` runs however many threads race their final releases, so
    /// `live` can never leak the way a failed `Arc::try_unwrap` pair
    /// could (both racers see strong_count > 1, neither recycles).
    fn drop(&mut self) {
        let Some(store) = self.store.upgrade() else {
            return; // store already gone — nothing to account to
        };
        // No `unwrap()`: a drop during a panicking unwind must not
        // escalate into an abort. A poisoned store still has sound
        // accounting state (plain counters + a buffer list), so take
        // the guard either way.
        let mut s = match store.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        s.live -= 1;
        s.free.push((std::mem::take(&mut self.k), std::mem::take(&mut self.v)));
    }
}

/// Snapshot of a [`PageStore`]'s accounting, for metrics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PageStats {
    /// Pages currently alive (held by caches or the prefix cache).
    pub live: usize,
    /// Recycled page buffers waiting on the free list.
    pub free: usize,
    /// High-water mark of `live`.
    pub peak_live: usize,
    /// Copy-on-write page copies performed.
    pub cow_pages: u64,
    /// Total fresh (non-recycled) page buffer allocations.
    pub page_allocs: u64,
    /// Page budget, if bounded.
    pub budget: Option<usize>,
}

#[derive(Debug)]
struct StoreInner {
    /// Floats per page per K (or V) buffer:
    /// `n_layers · n_kv_heads · page_size · head_dim`.
    page_floats: usize,
    /// Max live pages, `None` = unbounded.
    budget: Option<usize>,
    /// Recycled page buffers (k, v) awaiting reuse.
    free: Vec<(Box<[f32]>, Box<[f32]>)>,
    live: usize,
    peak_live: usize,
    cow_pages: u64,
    page_allocs: u64,
}

/// Shared page allocator: a free-list pool of fixed-geometry pages with
/// an optional budget. Cheap to clone (`Arc` handle); all caches of one
/// serving replica share one store so the budget bounds replica-wide KV
/// memory.
#[derive(Clone, Debug)]
pub struct PageStore {
    inner: Arc<Mutex<StoreInner>>,
}

impl PageStore {
    /// Store for pages of the given geometry. `budget` bounds the
    /// number of simultaneously live pages (`None` = unbounded).
    pub fn for_geometry(
        n_layers: usize,
        n_kv_heads: usize,
        head_dim: usize,
        page_size: usize,
        budget: Option<usize>,
    ) -> PageStore {
        PageStore {
            inner: Arc::new(Mutex::new(StoreInner {
                page_floats: n_layers * n_kv_heads * page_size * head_dim,
                budget,
                free: Vec::new(),
                live: 0,
                peak_live: 0,
                cow_pages: 0,
                page_allocs: 0,
            })),
        }
    }

    /// Allocate one zero-filled page (recycling a free buffer when one
    /// is available). Fails only when a budget is set and exhausted.
    pub fn alloc(&self) -> Result<Arc<KvPage>, PagesExhausted> {
        let mut s = self.inner.lock().unwrap();
        if let Some(b) = s.budget {
            if s.live >= b {
                return Err(PagesExhausted { budget: b });
            }
        }
        let (k, v) = match s.free.pop() {
            Some((mut k, mut v)) => {
                // Recycled buffers keep stale floats; that's fine —
                // readers never look past the staged horizon.
                debug_assert_eq!(k.len(), s.page_floats);
                k.fill(0.0);
                v.fill(0.0);
                (k, v)
            }
            None => {
                s.page_allocs += 1;
                let n = s.page_floats;
                (
                    vec![0.0f32; n].into_boxed_slice(),
                    vec![0.0f32; n].into_boxed_slice(),
                )
            }
        };
        s.live += 1;
        s.peak_live = s.peak_live.max(s.live);
        Ok(Arc::new(KvPage {
            k,
            v,
            store: Arc::downgrade(&self.inner),
        }))
    }

    /// Return one reference to a page. Only when this was the *last*
    /// reference does the page die and its buffers join the free list;
    /// shared pages just drop the refcount. The accounting itself runs
    /// in [`KvPage`]'s `Drop` (each page carries a weak store handle),
    /// so even a plain `Arc` drop — including two threads racing their
    /// final references — recycles correctly; this method is the
    /// semantic API, not the mechanism.
    pub fn release(&self, page: Arc<KvPage>) {
        drop(page);
    }

    /// Record one copy-on-write page copy (metrics only).
    pub fn note_cow(&self) {
        self.inner.lock().unwrap().cow_pages += 1;
    }

    pub fn stats(&self) -> PageStats {
        let s = self.inner.lock().unwrap();
        PageStats {
            live: s.live,
            free: s.free.len(),
            peak_live: s.peak_live,
            cow_pages: s.cow_pages,
            page_allocs: s.page_allocs,
            budget: s.budget,
        }
    }

    /// Floats per page per K (or V) buffer.
    pub fn page_floats(&self) -> usize {
        self.inner.lock().unwrap().page_floats
    }

    /// Whether two handles point at the same underlying store.
    pub fn ptr_eq(&self, other: &PageStore) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }
}

/// Zero-alloc iterator over the page chain of one (layer, kv-head)
/// block: yields `(keys, values)` slices of `fill · head_dim` floats
/// per page, in ascending position order. Produced by
/// [`KvCache::page_streams`].
pub struct PageStreams<'a> {
    pages: &'a [Arc<KvPage>],
    base: usize,
    page_positions: usize,
    head_dim: usize,
    remaining: usize,
    idx: usize,
}

impl<'a> Iterator for PageStreams<'a> {
    /// `(keys, values)` for one page: `fill · head_dim` floats each.
    type Item = (&'a [f32], &'a [f32]);

    fn next(&mut self) -> Option<Self::Item> {
        if self.remaining == 0 {
            return None;
        }
        let page = &self.pages[self.idx];
        let fill = self.remaining.min(self.page_positions);
        let lo = self.base;
        let hi = lo + fill * self.head_dim;
        self.idx += 1;
        self.remaining -= fill;
        Some((&page.k[lo..hi], &page.v[lo..hi]))
    }
}

/// Append-only cache for all layers of one sequence, head-major within
/// fixed-size refcounted pages (module docs have the layout).
///
/// `Clone` is a copy-on-write fork: the clone shares every page by
/// refcount; whichever side appends into a shared page first pays one
/// page copy. Forks at non-page-aligned boundaries are therefore safe —
/// the partially-filled tail page is duplicated lazily on first write.
#[derive(Debug)]
pub struct KvCache {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    page_size: usize,
    store: PageStore,
    /// Page table: position `pos` lives in `pages[pos / page_size]`.
    pages: Vec<Arc<KvPage>>,
    len: usize,
    /// Staged (appended, possibly uncommitted) positions per layer.
    staged: Vec<usize>,
}

impl Clone for KvCache {
    fn clone(&self) -> KvCache {
        KvCache {
            n_layers: self.n_layers,
            n_kv_heads: self.n_kv_heads,
            head_dim: self.head_dim,
            max_seq: self.max_seq,
            page_size: self.page_size,
            store: self.store.clone(),
            // Refcount bump only; store accounting is unchanged (the
            // pages stay live) and release() frees on last-ref drop.
            pages: self.pages.clone(),
            len: self.len,
            staged: self.staged.clone(),
        }
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        for page in self.pages.drain(..) {
            self.store.release(page);
        }
    }
}

impl KvCache {
    /// Legacy single-allocation cache: one page spanning `max_seq`
    /// positions over a private unbounded store. Byte layout inside
    /// that page is exactly the PR-5 contiguous head-major layout.
    pub fn new(n_layers: usize, n_kv_heads: usize, head_dim: usize, max_seq: usize) -> KvCache {
        let store = PageStore::for_geometry(n_layers, n_kv_heads, head_dim, max_seq.max(1), None);
        KvCache::paged(n_layers, n_kv_heads, head_dim, max_seq, max_seq, store)
    }

    /// Paged cache drawing pages of `page_size` positions from the
    /// shared `store` (whose geometry must match). `page_size` is
    /// clamped to `[1, max_seq]`.
    pub fn paged(
        n_layers: usize,
        n_kv_heads: usize,
        head_dim: usize,
        max_seq: usize,
        page_size: usize,
        store: PageStore,
    ) -> KvCache {
        let page_size = page_size.min(max_seq).max(1);
        debug_assert_eq!(
            store.page_floats(),
            n_layers * n_kv_heads * page_size * head_dim,
            "PageStore geometry must match the cache geometry"
        );
        KvCache {
            n_layers,
            n_kv_heads,
            head_dim,
            max_seq,
            page_size,
            store,
            pages: Vec::new(),
            len: 0,
            staged: vec![0; n_layers],
        }
    }

    /// Width of one position's K (or V) across all KV heads.
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Number of cached (committed) positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.max_seq
    }

    /// Committed positions still available before the cache is full.
    pub fn remaining(&self) -> usize {
        self.max_seq - self.len.min(self.max_seq)
    }

    /// Positions per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Pages currently held (shared or exclusive).
    pub fn pages_held(&self) -> usize {
        self.pages.len()
    }

    /// The store this cache allocates from.
    pub fn store(&self) -> &PageStore {
        &self.store
    }

    /// Offset of (layer, kvh)'s block inside a page's k/v buffer.
    #[inline]
    fn block_base(&self, layer: usize, kvh: usize) -> usize {
        debug_assert!(layer < self.n_layers && kvh < self.n_kv_heads);
        (layer * self.n_kv_heads + kvh) * self.page_size * self.head_dim
    }

    /// Make `pages[idx]` exclusively owned, cloning the payload first
    /// when it is shared (copy-on-write).
    fn ensure_writable(&mut self, idx: usize) -> Result<(), PagesExhausted> {
        if Arc::get_mut(&mut self.pages[idx]).is_some() {
            return Ok(());
        }
        let fresh = self.store.alloc()?;
        let old = std::mem::replace(&mut self.pages[idx], fresh);
        {
            // Freshly allocated ⇒ uniquely owned; copy the shared payload.
            let dst = Arc::get_mut(&mut self.pages[idx]).expect("fresh page is unshared");
            dst.k.copy_from_slice(&old.k);
            dst.v.copy_from_slice(&old.v);
        }
        self.store.release(old);
        self.store.note_cow();
        Ok(())
    }

    /// Pre-allocate pages (and un-share the partially-filled tail page)
    /// so the next `n` appended positions cannot fail mid-pass. The
    /// engine calls this at scheduling time and treats `Err` as a
    /// preemption signal; `append` itself then never allocates under a
    /// budget it could miss.
    pub fn reserve(&mut self, n: usize) -> Result<(), PagesExhausted> {
        let target = (self.len + n).min(self.max_seq);
        let need = target.div_ceil(self.page_size);
        while self.pages.len() < need {
            let page = self.store.alloc()?;
            self.pages.push(page);
        }
        // A fork may share the tail page; pay the COW copy now, under
        // the same budget, rather than inside the forward pass.
        if n > 0 && self.len % self.page_size != 0 {
            self.ensure_writable(self.len / self.page_size)?;
        }
        Ok(())
    }

    /// Append one position's K/V for layer `layer` (`k`/`v` are
    /// `kv_dim` long, `[head0 | head1 | ...]`); each head's chunk goes
    /// to that head's block of the position's page. Multiple positions
    /// may be staged per layer before a single [`KvCache::commit_n`]
    /// (the batched prefill path); the classic decode path appends one
    /// position per layer then calls [`KvCache::commit`]. Staged
    /// (uncommitted) positions are already visible through
    /// [`KvCache::page_streams`], which is what lets a prefill chunk
    /// attend to itself causally.
    ///
    /// Panics on overflow — callers that plan capacity (the engine)
    /// guard with [`KvCache::remaining`] + [`KvCache::reserve`] or use
    /// [`KvCache::try_append`] for the recoverable form.
    pub fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        if let Err(e) = self.try_append(layer, k, v) {
            panic!("KV cache overflow ({e})");
        }
    }

    /// [`KvCache::append`] returning the recoverable [`CacheFull`]
    /// signal instead of panicking; the cache is unchanged on `Err`.
    /// Both the `max_seq` ceiling and (when the caller skipped
    /// [`KvCache::reserve`]) page-pool exhaustion surface as
    /// `CacheFull` — capacity is capacity.
    pub fn try_append(&mut self, layer: usize, k: &[f32], v: &[f32]) -> Result<(), CacheFull> {
        debug_assert_eq!(k.len(), self.kv_dim());
        debug_assert_eq!(v.len(), self.kv_dim());
        let pos = self.staged[layer];
        if pos >= self.max_seq {
            return Err(CacheFull {
                max_seq: self.max_seq,
            });
        }
        let page_idx = pos / self.page_size;
        if page_idx >= self.pages.len() || Arc::get_mut(&mut self.pages[page_idx]).is_none() {
            // Un-reserved path (standalone callers): allocate / COW
            // here; a budget miss degrades to the CacheFull signal.
            if page_idx >= self.pages.len() {
                match self.store.alloc() {
                    Ok(p) => self.pages.push(p),
                    Err(_) => {
                        return Err(CacheFull {
                            max_seq: self.max_seq,
                        })
                    }
                }
            } else if self.ensure_writable(page_idx).is_err() {
                return Err(CacheFull {
                    max_seq: self.max_seq,
                });
            }
        }
        let hd = self.head_dim;
        let off = (pos % self.page_size) * hd;
        for kvh in 0..self.n_kv_heads {
            let base = self.block_base(layer, kvh) + off;
            let page = Arc::get_mut(&mut self.pages[page_idx]).expect("page made writable above");
            page.k[base..base + hd].copy_from_slice(&k[kvh * hd..(kvh + 1) * hd]);
            page.v[base..base + hd].copy_from_slice(&v[kvh * hd..(kvh + 1) * hd]);
        }
        self.staged[layer] = pos + 1;
        Ok(())
    }

    /// Staged positions for `layer`: committed length plus any appends
    /// not yet committed.
    pub fn staged_len(&self, layer: usize) -> usize {
        self.staged[layer]
    }

    /// Advance the position counter after all layers appended.
    pub fn commit(&mut self) {
        self.commit_n(1);
    }

    /// Advance the position counter by `n` after every layer received
    /// `n` staged appends (the batched forward path commits a whole
    /// prefill chunk at once).
    pub fn commit_n(&mut self, n: usize) {
        self.len += n;
        for layer in 0..self.n_layers {
            debug_assert_eq!(self.staged[layer], self.len);
        }
    }

    /// K block for one (layer, kv-head) when the whole sequence fits in
    /// one page (always true for [`KvCache::new`] caches): `staged ·
    /// head_dim` values, unit-stride — position `ti`'s key is `[ti·hd
    /// .. (ti+1)·hd]`. Paged callers iterate
    /// [`KvCache::page_streams`] instead.
    pub fn keys(&self, layer: usize, kvh: usize) -> &[f32] {
        let staged = self.staged[layer];
        assert!(
            staged <= self.page_size,
            "keys()/values() require a single-page cache (staged={staged} > page_size={})",
            self.page_size
        );
        if staged == 0 {
            return &[];
        }
        let base = self.block_base(layer, kvh);
        &self.pages[0].k[base..base + staged * self.head_dim]
    }

    pub fn values(&self, layer: usize, kvh: usize) -> &[f32] {
        let staged = self.staged[layer];
        assert!(
            staged <= self.page_size,
            "keys()/values() require a single-page cache (staged={staged} > page_size={})",
            self.page_size
        );
        if staged == 0 {
            return &[];
        }
        let base = self.block_base(layer, kvh);
        &self.pages[0].v[base..base + staged * self.head_dim]
    }

    /// Iterate the page chain of one (layer, kv-head) block over the
    /// first `t` positions: `(keys, values)` slices per page, ascending
    /// position order. `t` may include staged positions. The attention
    /// pass folds these **in yielded order**, which preserves the exact
    /// left-fold of the contiguous layout across page boundaries
    /// (DESIGN.md §Paged-KV bit-identity argument).
    pub fn page_streams(&self, layer: usize, kvh: usize, t: usize) -> PageStreams<'_> {
        debug_assert!(t <= self.staged[layer]);
        PageStreams {
            pages: &self.pages,
            base: self.block_base(layer, kvh),
            page_positions: self.page_size,
            head_dim: self.head_dim,
            remaining: t,
            idx: 0,
        }
    }

    /// Adopt fully-filled pages (a prefix-cache hit) into an empty
    /// cache: the cache now starts at `pages.len() · page_size`
    /// committed positions without prefilling them.
    pub fn adopt_pages(&mut self, pages: Vec<Arc<KvPage>>) {
        assert!(
            self.pages.is_empty() && self.len == 0,
            "adopt_pages requires an empty cache"
        );
        debug_assert!(pages
            .iter()
            .all(|p| p.k.len() == self.store.page_floats()));
        let n = pages.len() * self.page_size;
        debug_assert!(n <= self.max_seq);
        self.pages = pages;
        self.len = n;
        for s in self.staged.iter_mut() {
            *s = n;
        }
    }

    /// The first `n_positions` worth of pages, for donation to the
    /// prefix cache (`n_positions` must be page-aligned and committed).
    pub fn shared_pages(&self, n_positions: usize) -> &[Arc<KvPage>] {
        debug_assert_eq!(n_positions % self.page_size, 0);
        debug_assert!(n_positions <= self.len);
        &self.pages[..n_positions / self.page_size]
    }

    /// Copy-on-write fork: shares every page by refcount; either side
    /// pays one page copy on its first write into a shared page.
    pub fn fork(&self) -> KvCache {
        self.clone()
    }

    /// Drop all cached state (pages go back to the store).
    pub fn reset(&mut self) {
        for page in self.pages.drain(..) {
            self.store.release(page);
        }
        self.len = 0;
        for s in self.staged.iter_mut() {
            *s = 0;
        }
    }

    /// Truncate to the first `keep` positions (speculative rollback).
    /// Pages past the new tail go back to the store; stale floats
    /// beyond `keep` inside the tail page are never read (all reads are
    /// bounded by the staged horizon).
    pub fn truncate(&mut self, keep: usize) {
        let keep = keep.min(self.len);
        let keep_pages = keep.div_ceil(self.page_size);
        while self.pages.len() > keep_pages {
            let page = self.pages.pop().expect("len checked");
            self.store.release(page);
        }
        self.len = keep;
        for s in self.staged.iter_mut() {
            *s = keep;
        }
    }

    /// Resident bytes (pages held by this cache, shared or not).
    pub fn bytes(&self) -> usize {
        self.pages.len() * 2 * self.store.page_floats() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_commit_cycle() {
        // 2 layers, 2 kv-heads × head_dim 2 (kv_dim 4)
        let mut c = KvCache::new(2, 2, 2, 8);
        for step in 0..3 {
            for layer in 0..2 {
                let k = vec![step as f32; 4];
                let v = vec![-(step as f32); 4];
                c.append(layer, &k, &v);
            }
            c.commit();
        }
        assert_eq!(c.len(), 3);
        assert_eq!(c.kv_dim(), 4);
        // per-head blocks hold len × head_dim values each
        assert_eq!(c.keys(0, 0).len(), 6);
        assert_eq!(c.keys(1, 1).len(), 6);
        assert_eq!(c.keys(1, 0)[4], 2.0);
        assert_eq!(c.values(1, 1)[4], -2.0);
    }

    #[test]
    fn head_major_blocks_are_contiguous_per_head() {
        // distinct per-head values must land in distinct contiguous blocks
        let mut c = KvCache::new(1, 3, 2, 4);
        for pos in 0..3 {
            // head h carries value 10·h + pos
            let k: Vec<f32> = (0..3)
                .flat_map(|h| [(10 * h + pos) as f32; 2])
                .collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            c.append(0, &k, &v);
            c.commit();
        }
        for h in 0..3 {
            let block = c.keys(0, h);
            assert_eq!(block.len(), 6);
            for pos in 0..3 {
                // position ti of head h is the unit-stride slice [ti·hd..]
                assert_eq!(block[pos * 2], (10 * h + pos) as f32);
                assert_eq!(block[pos * 2 + 1], (10 * h + pos) as f32);
                assert_eq!(c.values(0, h)[pos * 2], -((10 * h + pos) as f32));
            }
        }
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1, 2, 1);
        c.append(0, &[0.0, 0.0], &[0.0, 0.0]);
        c.commit();
        c.append(0, &[1.0, 1.0], &[1.0, 1.0]);
    }

    #[test]
    fn try_append_surfaces_recoverable_cache_full() {
        let mut c = KvCache::new(1, 1, 2, 1);
        assert_eq!(c.remaining(), 1);
        assert!(c.try_append(0, &[0.0, 0.0], &[0.0, 0.0]).is_ok());
        c.commit();
        assert_eq!(c.remaining(), 0);
        // no panic: the full cache reports a typed, recoverable error
        let err = c.try_append(0, &[1.0, 1.0], &[1.0, 1.0]).unwrap_err();
        assert_eq!(err, CacheFull { max_seq: 1 });
        assert!(err.to_string().contains("max_seq=1"));
        // and the cache is unchanged — still servable
        assert_eq!(c.len(), 1);
        assert_eq!(c.keys(0, 0), &[0.0, 0.0]);
    }

    #[test]
    fn truncate_rolls_back() {
        let mut c = KvCache::new(1, 1, 2, 8);
        for i in 0..4 {
            c.append(0, &[i as f32, 0.0], &[0.0, 0.0]);
            c.commit();
        }
        c.truncate(2);
        assert_eq!(c.len(), 2);
        assert_eq!(c.keys(0, 0).len(), 4);
        // can append again
        c.append(0, &[9.0, 9.0], &[0.0, 0.0]);
        c.commit();
        assert_eq!(c.keys(0, 0)[4], 9.0);
    }

    #[test]
    fn multi_append_then_commit_n() {
        // batched prefill: stage a whole chunk per layer, commit once
        let mut c = KvCache::new(2, 1, 2, 8);
        for layer in 0..2 {
            for p in 0..3 {
                c.append(layer, &[p as f32, 0.0], &[0.0, p as f32]);
            }
            assert_eq!(c.staged_len(layer), 3);
        }
        assert_eq!(c.len(), 0, "not yet committed");
        // staged K/V already visible (prefill chunk self-attention)
        assert_eq!(c.keys(0, 0).len(), 6);
        assert_eq!(c.keys(1, 0)[4], 2.0);
        c.commit_n(3);
        assert_eq!(c.len(), 3);
        // and the cache keeps working with classic single commits
        c.append(0, &[9.0, 9.0], &[0.0, 0.0]);
        c.append(1, &[9.0, 9.0], &[0.0, 0.0]);
        c.commit();
        assert_eq!(c.len(), 4);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn staged_overflow_panics() {
        let mut c = KvCache::new(1, 1, 2, 2);
        c.append(0, &[0.0; 2], &[0.0; 2]);
        c.append(0, &[0.0; 2], &[0.0; 2]);
        c.append(0, &[0.0; 2], &[0.0; 2]); // third staged position > max_seq
    }

    #[test]
    fn reset_reuses() {
        let mut c = KvCache::new(1, 1, 2, 4);
        c.append(0, &[1.0, 1.0], &[1.0, 1.0]);
        c.commit();
        c.reset();
        assert!(c.is_empty());
        assert!(!c.is_full());
        assert_eq!(c.remaining(), 4);
    }

    // ---- paged-specific coverage ----

    fn paged_cache(page_size: usize, max_seq: usize, budget: Option<usize>) -> KvCache {
        let store = PageStore::for_geometry(1, 1, 2, page_size, budget);
        KvCache::paged(1, 1, 2, max_seq, page_size, store)
    }

    fn fill(c: &mut KvCache, n: usize, tag: f32) {
        for i in 0..n {
            let x = tag + i as f32;
            c.append(0, &[x, x], &[-x, -x]);
            c.commit();
        }
    }

    #[test]
    fn page_table_math_spans_pages() {
        // page_size 2, 5 positions ⇒ pages [2, 2, 1]
        let mut c = paged_cache(2, 8, None);
        fill(&mut c, 5, 0.0);
        assert_eq!(c.pages_held(), 3);
        let chunks: Vec<(Vec<f32>, Vec<f32>)> = c
            .page_streams(0, 0, 5)
            .map(|(k, v)| (k.to_vec(), v.to_vec()))
            .collect();
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].0, vec![0.0, 0.0, 1.0, 1.0]);
        assert_eq!(chunks[1].0, vec![2.0, 2.0, 3.0, 3.0]);
        assert_eq!(chunks[2].0, vec![4.0, 4.0]); // partial tail page
        assert_eq!(chunks[2].1, vec![-4.0, -4.0]);
        // a shorter horizon stops mid-chain
        let short: Vec<usize> = c.page_streams(0, 0, 3).map(|(k, _)| k.len()).collect();
        assert_eq!(short, vec![4, 2]);
    }

    #[test]
    fn cow_fork_isolates_writers_at_unaligned_boundary() {
        // fork at position 3 with page_size 2: tail page is half full
        let mut a = paged_cache(2, 8, None);
        fill(&mut a, 3, 0.0);
        let mut b = a.fork();
        let stats_before = a.store().stats();
        assert_eq!(stats_before.live, 2, "fork shares pages physically");
        // both sides write into the shared tail page → one COW copy each side at most
        fill(&mut a, 1, 100.0);
        fill(&mut b, 1, 200.0);
        let a_tail: Vec<f32> = a.page_streams(0, 0, 4).last().unwrap().0.to_vec();
        let b_tail: Vec<f32> = b.page_streams(0, 0, 4).last().unwrap().0.to_vec();
        assert_eq!(a_tail, vec![2.0, 2.0, 100.0, 100.0]);
        assert_eq!(b_tail, vec![2.0, 2.0, 200.0, 200.0]);
        // shared full first page untouched and still shared
        let a_head: Vec<f32> = a.page_streams(0, 0, 2).next().unwrap().0.to_vec();
        let b_head: Vec<f32> = b.page_streams(0, 0, 2).next().unwrap().0.to_vec();
        assert_eq!(a_head, b_head);
        assert!(a.store().stats().cow_pages >= 1);
    }

    #[test]
    fn drop_returns_pages_to_free_list() {
        let store = PageStore::for_geometry(1, 1, 2, 2, None);
        let mut c = KvCache::paged(1, 1, 2, 8, 2, store.clone());
        fill(&mut c, 4, 0.0);
        assert_eq!(store.stats().live, 2);
        drop(c);
        let s = store.stats();
        assert_eq!(s.live, 0);
        assert_eq!(s.free, 2, "buffers recycled, not leaked");
        // a new cache reuses the freed buffers without fresh allocs
        let allocs_before = s.page_allocs;
        let mut c2 = KvCache::paged(1, 1, 2, 8, 2, store.clone());
        fill(&mut c2, 4, 0.0);
        assert_eq!(store.stats().page_allocs, allocs_before);
    }

    #[test]
    fn racing_final_releases_never_leak_live_count() {
        // regression: release() used Arc::try_unwrap, so two threads
        // dropping the last two references concurrently could BOTH see
        // strong_count > 1, neither recycled, and `live` leaked —
        // permanently shrinking a budgeted store. Accounting now runs
        // in KvPage::Drop (exactly one drop runs per page, whichever
        // thread loses the race), so live returns to 0 every time.
        let st = PageStore::for_geometry(1, 1, 2, 4, Some(8));
        for _ in 0..200 {
            let p = st.alloc().unwrap();
            let q = Arc::clone(&p);
            let (s1, s2) = (st.clone(), st.clone());
            let t1 = std::thread::spawn(move || s1.release(p));
            let t2 = std::thread::spawn(move || s2.release(q));
            t1.join().unwrap();
            t2.join().unwrap();
            let s = st.stats();
            assert_eq!(s.live, 0, "racing final releases must not leak live pages");
            assert_eq!(s.free, 1, "the dead page's buffers were recycled");
        }
        // the budget never spuriously binds afterwards
        for _ in 0..8 {
            assert!(st.alloc().is_ok());
        }
    }

    #[test]
    fn budget_exhaustion_is_recoverable_and_reserve_preflights() {
        let mut c = paged_cache(2, 64, Some(2));
        assert!(c.reserve(4).is_ok()); // exactly 2 pages
        fill(&mut c, 4, 0.0);
        // a 5th position needs a 3rd page: reserve fails, cache unchanged
        let err = c.reserve(1).unwrap_err();
        assert_eq!(err, PagesExhausted { budget: 2 });
        assert!(err.to_string().contains("budget=2"));
        assert_eq!(c.len(), 4);
        // un-reserved append degrades to the CacheFull signal
        let err = c.try_append(0, &[9.0, 9.0], &[9.0, 9.0]).unwrap_err();
        assert_eq!(err.max_seq, 64);
        // freeing a page makes progress possible again
        c.truncate(2);
        assert!(c.reserve(1).is_ok());
    }

    #[test]
    fn adopt_pages_skips_prefill_and_matches_donor() {
        let store = PageStore::for_geometry(1, 1, 2, 2, None);
        let mut donor = KvCache::paged(1, 1, 2, 8, 2, store.clone());
        fill(&mut donor, 4, 7.0);
        let shared: Vec<_> = donor.shared_pages(4).to_vec();
        let mut adopter = KvCache::paged(1, 1, 2, 8, 2, store.clone());
        adopter.adopt_pages(shared);
        assert_eq!(adopter.len(), 4);
        assert_eq!(adopter.staged_len(0), 4);
        let d: Vec<f32> = donor.page_streams(0, 0, 4).flat_map(|(k, _)| k.to_vec()).collect();
        let a: Vec<f32> = adopter.page_streams(0, 0, 4).flat_map(|(k, _)| k.to_vec()).collect();
        assert_eq!(d, a, "adopted prefix is the same physical bytes");
        // adopter can extend past the adopted prefix independently
        fill(&mut adopter, 1, 50.0);
        assert_eq!(adopter.len(), 5);
        assert_eq!(donor.len(), 4);
    }

    #[test]
    fn single_page_streams_match_keys_values() {
        let mut c = KvCache::new(2, 2, 3, 6);
        for layer in 0..2 {
            for p in 0..4 {
                let k: Vec<f32> = (0..6).map(|j| (layer * 100 + p * 10 + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
                c.append(layer, &k, &v);
            }
        }
        c.commit_n(4);
        for layer in 0..2 {
            for kvh in 0..2 {
                let mut it = c.page_streams(layer, kvh, 4);
                let (k, v) = it.next().unwrap();
                assert!(it.next().is_none(), "single page for legacy caches");
                assert_eq!(k, &c.keys(layer, kvh)[..4 * 3]);
                assert_eq!(v, &c.values(layer, kvh)[..4 * 3]);
            }
        }
    }

    #[test]
    fn truncate_releases_whole_pages() {
        let store = PageStore::for_geometry(1, 1, 2, 2, None);
        let mut c = KvCache::paged(1, 1, 2, 16, 2, store.clone());
        fill(&mut c, 7, 0.0); // 4 pages
        assert_eq!(c.pages_held(), 4);
        c.truncate(3); // keeps 2 pages (positions 0..3)
        assert_eq!(c.pages_held(), 2);
        assert_eq!(store.stats().live, 2);
        assert_eq!(store.stats().free, 2);
        // appending after truncate overwrites the stale tail slot
        fill(&mut c, 1, 30.0);
        let tail: Vec<f32> = c.page_streams(0, 0, 4).last().unwrap().0.to_vec();
        assert_eq!(tail, vec![2.0, 2.0, 30.0, 30.0]);
    }
}

//! Model configuration + the synthetic "family sizes" standing in for
//! the paper's 0.6B–70B evaluation grid (see DESIGN.md §2).

use crate::serialize::Json;

/// Transformer hyper-parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// KV heads for GQA; must divide `n_heads`.
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
    pub norm_eps: f32,
    /// Tie lm_head to tok_embed (saves params on tiny models).
    pub tied_embeddings: bool,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Total parameter count (embeddings + blocks + head).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let embed = self.vocab_size * d;
        let head = if self.tied_embeddings { 0 } else { self.vocab_size * d };
        let attn = d * d + 2 * d * self.kv_dim() + d * d; // wq wk wv wo
        let mlp = 3 * d * self.d_ff; // gate, up, down
        let norms = 2 * d;
        embed + head + self.n_layers * (attn + mlp + norms) + d
    }

    /// The size grid used by the benches, mirroring the paper's model
    /// families (scaled to this testbed: see DESIGN.md substitutions).
    pub fn family(name: &str) -> anyhow::Result<ModelConfig> {
        let base = |name: &str, d, l, h, kv, ff| ModelConfig {
            name: name.to_string(),
            vocab_size: 0, // filled from tokenizer at train/load time
            d_model: d,
            n_layers: l,
            n_heads: h,
            n_kv_heads: kv,
            d_ff: ff,
            max_seq: 256,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
            tied_embeddings: true,
        };
        Ok(match name {
            // "0.6B-class" stand-in
            "tiny" => base("tiny", 64, 2, 4, 2, 172),
            // "1.7B-class" stand-in
            "small" => base("small", 128, 4, 4, 2, 344),
            // "4B-class" stand-in
            "medium" => base("medium", 192, 6, 6, 3, 512),
            // "8B-class" stand-in (used by ablations only by default)
            "large" => base("large", 256, 8, 8, 4, 688),
            other => anyhow::bail!("unknown model family '{other}'"),
        })
    }

    pub fn families() -> Vec<&'static str> {
        vec!["tiny", "small", "medium", "large"]
    }

    // ---------- json ----------

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("vocab_size", self.vocab_size)
            .set("d_model", self.d_model)
            .set("n_layers", self.n_layers)
            .set("n_heads", self.n_heads)
            .set("n_kv_heads", self.n_kv_heads)
            .set("d_ff", self.d_ff)
            .set("max_seq", self.max_seq)
            .set("rope_theta", self.rope_theta as f64)
            .set("norm_eps", self.norm_eps as f64)
            .set("tied_embeddings", self.tied_embeddings)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.req_str("name")?.to_string(),
            vocab_size: j.req_usize("vocab_size")?,
            d_model: j.req_usize("d_model")?,
            n_layers: j.req_usize("n_layers")?,
            n_heads: j.req_usize("n_heads")?,
            n_kv_heads: j.req_usize("n_kv_heads")?,
            d_ff: j.req_usize("d_ff")?,
            max_seq: j.req_usize("max_seq")?,
            rope_theta: j.req_f64("rope_theta")? as f32,
            norm_eps: j.req_f64("norm_eps")? as f32,
            tied_embeddings: j
                .get("tied_embeddings")
                .and_then(Json::as_bool)
                .unwrap_or(true),
        })
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<ModelConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("read {:?}: {e}", path.as_ref()))?;
        ModelConfig::from_json(&Json::parse(&text)?)
    }

    /// Validate internal consistency; call after construction/load.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.d_model % self.n_heads == 0, "d_model % n_heads != 0");
        anyhow::ensure!(self.n_heads % self.n_kv_heads == 0, "n_heads % n_kv_heads != 0");
        anyhow::ensure!(self.vocab_size > 0, "vocab_size unset");
        anyhow::ensure!(self.max_seq > 0, "max_seq must be positive");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn families_resolve_and_scale() {
        let mut prev = 0usize;
        for f in ModelConfig::families() {
            let mut c = ModelConfig::family(f).unwrap();
            c.vocab_size = 96;
            c.validate().unwrap();
            let p = c.param_count();
            assert!(p > prev, "{f} should be bigger than previous");
            prev = p;
        }
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ModelConfig::family("small").unwrap();
        c.vocab_size = 101;
        let j = c.to_json();
        let back = ModelConfig::from_json(&j).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn gqa_dims() {
        let mut c = ModelConfig::family("medium").unwrap();
        c.vocab_size = 96;
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.kv_dim(), 96);
        c.validate().unwrap();
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ModelConfig::family("tiny").unwrap();
        c.vocab_size = 96;
        c.n_kv_heads = 3; // 4 % 3 != 0
        assert!(c.validate().is_err());
        let mut c2 = ModelConfig::family("tiny").unwrap();
        c2.vocab_size = 0;
        assert!(c2.validate().is_err());
    }

    #[test]
    fn unknown_family_errors() {
        assert!(ModelConfig::family("70b").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut c = ModelConfig::family("tiny").unwrap();
        c.vocab_size = 77;
        let p = std::env::temp_dir().join("ptqtp_cfg_test.json");
        c.save(&p).unwrap();
        assert_eq!(ModelConfig::load(&p).unwrap(), c);
        std::fs::remove_file(p).ok();
    }
}

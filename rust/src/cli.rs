//! Argument-parsing substrate (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands, with typed accessors and generated usage text.

use std::collections::BTreeMap;

/// Declarative option spec for usage rendering.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub program: String,
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding the program name if
    /// you pass `std::env::args().skip(1)`, or including it via
    /// [`Args::from_env`]).
    pub fn parse<I: IntoIterator<Item = String>>(program: &str, raw: I, subcommands: &[&str]) -> Args {
        let mut args = Args {
            program: program.to_string(),
            ..Default::default()
        };
        let mut iter = raw.into_iter().peekable();
        // subcommand = first non-dash token if it matches the table
        if let Some(first) = iter.peek() {
            if !first.starts_with('-') && subcommands.contains(&first.as_str()) {
                args.subcommand = Some(iter.next().unwrap());
            }
        }
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    // value-taking if next token exists and is not --opt
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            args.options.insert(stripped.to_string(), v);
                        }
                        _ => args.flags.push(stripped.to_string()),
                    }
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn from_env(subcommands: &[&str]) -> Args {
        let mut raw = std::env::args();
        let program = raw.next().unwrap_or_else(|| "ptqtp".into());
        Args::parse(&program, raw, subcommands)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f32_or(&self, name: &str, default: f32) -> f32 {
        self.f64_or(name, default as f64) as f32
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Optional unsigned-integer option: `None` when absent (caller
    /// falls back to its env/default chain), `Some(n)` when present and
    /// parseable, and a helpful error otherwise — unlike [`usize_or`],
    /// which silently swallows typos into the default. Used by
    /// `--page-size` / `--kv-pages`, where a mis-typed value must not
    /// quietly become a different cache geometry.
    ///
    /// [`usize_or`]: Args::usize_or
    pub fn usize_opt(&self, name: &str) -> anyhow::Result<Option<usize>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                anyhow::anyhow!("invalid --{name} '{v}' (expected an unsigned integer)")
            }),
        }
    }

    /// Optional millisecond-duration option built on [`usize_opt`]:
    /// `None` when absent, `Some(duration)` when present and
    /// parseable, and the same helpful error on typos. Used by
    /// `--deadline-ms`, where a silent fallback would quietly serve
    /// without any deadline at all.
    ///
    /// [`usize_opt`]: Args::usize_opt
    pub fn duration_ms_opt(&self, name: &str) -> anyhow::Result<Option<std::time::Duration>> {
        Ok(self
            .usize_opt(name)?
            .map(|ms| std::time::Duration::from_millis(ms as u64)))
    }

    /// Worker-lane count for the row-parallel kernels. Resolution
    /// order: `--threads N` > `PTQTP_THREADS` env var > available
    /// cores; `1` forces the exact sequential path (the documented
    /// debugging escape hatch).
    pub fn threads_or_default(&self) -> usize {
        self.usize_or("threads", crate::threads::default_threads()).max(1)
    }

    /// Required string option with a helpful error.
    pub fn require(&self, name: &str) -> anyhow::Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    /// Enumerated option: `None` when absent (caller falls back to its
    /// env/default chain), `Some(value)` when present and legal, and a
    /// helpful error naming the allowed values otherwise. Used by
    /// `--simd auto|on|off`.
    pub fn choice<'a>(&'a self, name: &str, allowed: &[&str]) -> anyhow::Result<Option<&'a str>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) if allowed.contains(&v) => Ok(Some(v)),
            Some(v) => Err(anyhow::anyhow!(
                "invalid --{name} '{v}' (expected one of: {})",
                allowed.join("|")
            )),
        }
    }

    /// Three-valued switch option shared by `--simd`, `--act-quant`,
    /// and `--prefix-cache`: `None` when absent (caller falls back to
    /// its env/default chain), `Some(state)` when present and legal,
    /// and the [`Args::choice`] error naming the allowed values
    /// otherwise. `allow_auto` is `false` for strictly binary switches
    /// (`--prefix-cache` has no process-detected default to defer to).
    pub fn tri_state_opt(&self, name: &str, allow_auto: bool) -> anyhow::Result<Option<TriState>> {
        let allowed: &[&str] = if allow_auto {
            &["auto", "on", "off"]
        } else {
            &["on", "off"]
        };
        Ok(self.choice(name, allowed)?.map(|v| match v {
            "auto" => TriState::Auto,
            "on" => TriState::On,
            _ => TriState::Off,
        }))
    }

    /// Binary on/off switch with an env-var fallback, the resolution
    /// chain `--{name} on|off` > `{env_var}` > `None` (caller applies
    /// its default). The env var accepts the same spellings the other
    /// `PTQTP_*` switches do (`on`/`1`/`true`, `off`/`0`/`false`,
    /// case-insensitive); anything else is a helpful error, never a
    /// silent default. Used by `--spec-decode` / `PTQTP_SPEC_DECODE`.
    pub fn on_off_env(&self, name: &str, env_var: &str) -> anyhow::Result<Option<bool>> {
        if let Some(state) = self.tri_state_opt(name, false)? {
            return Ok(Some(state == TriState::On));
        }
        match std::env::var(env_var) {
            Err(_) => Ok(None),
            Ok(v) => match v.trim().to_ascii_lowercase().as_str() {
                "on" | "1" | "true" => Ok(Some(true)),
                "off" | "0" | "false" => Ok(Some(false)),
                other => Err(anyhow::anyhow!(
                    "invalid {env_var} '{other}' (expected on|off)"
                )),
            },
        }
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

/// Resolved value of a three-valued switch option (see
/// [`Args::tri_state_opt`]). `Auto` defers to the option's
/// env-var/detection chain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TriState {
    Auto,
    On,
    Off,
}

impl TriState {
    /// The canonical spelling (`"auto"`/`"on"`/`"off"`), e.g. for
    /// forwarding into an env-var style mode parser.
    pub fn as_str(self) -> &'static str {
        match self {
            TriState::Auto => "auto",
            TriState::On => "on",
            TriState::Off => "off",
        }
    }
}

/// Render usage text from a spec table.
pub fn usage(program: &str, about: &str, subcommands: &[(&str, &str)], opts: &[OptSpec]) -> String {
    let mut out = format!("{program} — {about}\n\nUSAGE:\n  {program} <command> [options]\n");
    if !subcommands.is_empty() {
        out.push_str("\nCOMMANDS:\n");
        for (name, help) in subcommands {
            out.push_str(&format!("  {name:<14} {help}\n"));
        }
    }
    if !opts.is_empty() {
        out.push_str("\nOPTIONS:\n");
        for o in opts {
            let default = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            out.push_str(&format!("  --{:<16} {}{}\n", o.name, o.help, default));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(
            "ptqtp",
            tokens.iter().map(|s| s.to_string()),
            &["quantize", "serve", "bench"],
        )
    }

    #[test]
    fn subcommand_detected() {
        let a = parse(&["quantize", "--g", "128"]);
        assert_eq!(a.subcommand.as_deref(), Some("quantize"));
        assert_eq!(a.usize_or("g", 0), 128);
    }

    #[test]
    fn equals_form() {
        let a = parse(&["bench", "--table=5", "--eps=1e-4"]);
        assert_eq!(a.usize_or("table", 0), 5);
        assert!((a.f64_or("eps", 0.0) - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn flags_vs_options() {
        let a = parse(&["serve", "--verbose", "--port", "8080", "--dry-run"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("dry-run"));
        assert_eq!(a.usize_or("port", 0), 8080);
    }

    #[test]
    fn threads_option_overrides_default() {
        let a = parse(&["serve", "--threads", "3"]);
        assert_eq!(a.threads_or_default(), 3);
        let b = parse(&["serve", "--threads", "0"]);
        assert_eq!(b.threads_or_default(), 1, "clamped to ≥ 1");
        assert!(parse(&["serve"]).threads_or_default() >= 1);
    }

    #[test]
    fn choice_validates_values() {
        let a = parse(&["serve", "--simd", "off"]);
        assert_eq!(a.choice("simd", &["auto", "on", "off"]).unwrap(), Some("off"));
        assert_eq!(parse(&["serve"]).choice("simd", &["auto", "on", "off"]).unwrap(), None);
        let e = parse(&["serve", "--simd", "sideways"])
            .choice("simd", &["auto", "on", "off"])
            .unwrap_err()
            .to_string();
        assert!(e.contains("--simd") && e.contains("auto|on|off"), "{e}");
    }

    #[test]
    fn usize_opt_distinguishes_absent_from_invalid() {
        assert_eq!(parse(&["serve"]).usize_opt("page-size").unwrap(), None);
        let a = parse(&["serve", "--page-size", "64"]);
        assert_eq!(a.usize_opt("page-size").unwrap(), Some(64));
        let e = parse(&["serve", "--page-size", "sixty"])
            .usize_opt("page-size")
            .unwrap_err()
            .to_string();
        assert!(e.contains("--page-size") && e.contains("'sixty'"), "{e}");
    }

    #[test]
    fn duration_ms_opt_parses_millis() {
        assert_eq!(parse(&["serve"]).duration_ms_opt("deadline-ms").unwrap(), None);
        let a = parse(&["serve", "--deadline-ms", "2500"]);
        assert_eq!(
            a.duration_ms_opt("deadline-ms").unwrap(),
            Some(std::time::Duration::from_millis(2500))
        );
        let e = parse(&["serve", "--deadline-ms", "soon"])
            .duration_ms_opt("deadline-ms")
            .unwrap_err()
            .to_string();
        assert!(e.contains("--deadline-ms") && e.contains("'soon'"), "{e}");
    }

    #[test]
    fn tri_state_accepts_legal_values_and_rejects_typos() {
        // absent → None (env/default chain decides)
        assert_eq!(parse(&["serve"]).tri_state_opt("act-quant", true).unwrap(), None);
        // each legal spelling maps to its state
        for (v, want) in [("auto", TriState::Auto), ("on", TriState::On), ("off", TriState::Off)] {
            let a = parse(&["serve", "--act-quant", v]);
            assert_eq!(a.tri_state_opt("act-quant", true).unwrap(), Some(want));
            assert_eq!(want.as_str(), v);
        }
        // invalid value: a helpful error, not a silent default
        let e = parse(&["serve", "--act-quant", "int8"])
            .tri_state_opt("act-quant", true)
            .unwrap_err()
            .to_string();
        assert!(e.contains("--act-quant") && e.contains("auto|on|off"), "{e}");
    }

    #[test]
    fn tri_state_binary_form_rejects_auto() {
        // --prefix-cache has no detection chain, so "auto" is illegal
        let a = parse(&["serve", "--prefix-cache", "off"]);
        assert_eq!(a.tri_state_opt("prefix-cache", false).unwrap(), Some(TriState::Off));
        let e = parse(&["serve", "--prefix-cache", "auto"])
            .tri_state_opt("prefix-cache", false)
            .unwrap_err()
            .to_string();
        assert!(e.contains("on|off") && !e.contains("auto|"), "{e}");
    }

    #[test]
    fn on_off_env_resolution_chain() {
        // unique var name: tests run in parallel and env is process-global
        let var = "PTQTP_TEST_SPEC_SWITCH";
        std::env::remove_var(var);
        // absent everywhere → None (caller's default decides)
        assert_eq!(parse(&["serve"]).on_off_env("spec-decode", var).unwrap(), None);
        // CLI alone
        let a = parse(&["serve", "--spec-decode", "on"]);
        assert_eq!(a.on_off_env("spec-decode", var).unwrap(), Some(true));
        // env alone, all accepted spellings
        for (v, want) in [("on", true), ("1", true), ("TRUE", true), ("off", false), ("0", false), ("False", false)] {
            std::env::set_var(var, v);
            assert_eq!(parse(&["serve"]).on_off_env("spec-decode", var).unwrap(), Some(want), "{v}");
        }
        // CLI beats env
        std::env::set_var(var, "on");
        let a = parse(&["serve", "--spec-decode", "off"]);
        assert_eq!(a.on_off_env("spec-decode", var).unwrap(), Some(false));
        // junk env is an error, not a silent default
        std::env::set_var(var, "maybe");
        let e = parse(&["serve"]).on_off_env("spec-decode", var).unwrap_err().to_string();
        assert!(e.contains(var) && e.contains("'maybe'"), "{e}");
        // junk CLI is the tri_state error
        std::env::remove_var(var);
        let e = parse(&["serve", "--spec-decode", "fast"])
            .on_off_env("spec-decode", var)
            .unwrap_err()
            .to_string();
        assert!(e.contains("--spec-decode") && e.contains("on|off"), "{e}");
    }

    #[test]
    fn positional_collected() {
        let a = parse(&["quantize", "model.ptw", "out.ptw"]);
        assert_eq!(a.positional, vec!["model.ptw", "out.ptw"]);
    }

    #[test]
    fn negative_number_value() {
        let a = parse(&["bench", "--offset", "-3"]);
        assert_eq!(a.get("offset"), Some("-3"));
    }

    #[test]
    fn unknown_first_token_is_positional() {
        let a = parse(&["nonsense", "--x", "1"]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.positional, vec!["nonsense"]);
    }

    #[test]
    fn require_errors_helpfully() {
        let a = parse(&["serve"]);
        let e = a.require("model").unwrap_err().to_string();
        assert!(e.contains("--model"));
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["bench", "--methods", "ptqtp, gptq ,awq"]);
        assert_eq!(a.list_or("methods", &[]), vec!["ptqtp", "gptq", "awq"]);
    }

    #[test]
    fn usage_renders() {
        let u = usage(
            "ptqtp",
            "trit-plane quantization",
            &[("quantize", "quantize a checkpoint")],
            &[OptSpec {
                name: "group-size",
                help: "group size G",
                default: Some("128"),
            }],
        );
        assert!(u.contains("quantize"));
        assert!(u.contains("group-size"));
        assert!(u.contains("default: 128"));
    }
}

//! Deterministic PRNG substrate (xoshiro256** + SplitMix64 seeding).
//!
//! The offline crate cache has no `rand`; every stochastic component in
//! this repo (corpus generation, weight init, sampling, property tests)
//! draws from this module so runs are bit-reproducible from a `u64` seed.

/// xoshiro256** generator (Blackman & Vigna). Passes BigCrush; 2^256-1
/// period; plenty for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small / similar seeds still produce
    /// well-distributed initial states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream (for parallel workers / sub-tasks).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). Lemire multiply-shift.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Student-t with `df` degrees of freedom — used to synthesize the
    /// heavy-tailed weight distributions (outliers) real LLMs exhibit.
    pub fn student_t(&mut self, df: f32) -> f32 {
        let z = self.normal();
        let mut chi = 0.0f32;
        let k = df.max(1.0) as usize;
        for _ in 0..k {
            let n = self.normal();
            chi += n * n;
        }
        z / (chi / df).sqrt()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut x = self.f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fill a slice with iid normals scaled by `std`.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = self.normal() * std;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for n in [1usize, 2, 3, 17, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..10_000 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn student_t_heavier_tails_than_normal() {
        let mut r = Rng::new(17);
        let n = 30_000;
        let t_extreme = (0..n).filter(|_| r.student_t(3.0).abs() > 4.0).count();
        let z_extreme = (0..n).filter(|_| r.normal().abs() > 4.0).count();
        assert!(t_extreme > z_extreme, "t {t_extreme} vs z {z_extreme}");
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Rng::new(19);
        let w = [1.0f32, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(31);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
